"""HAIL flight recorder: metrics registry, span tracing, per-query EXPLAIN.

Three seams over the same runtime:

* ``obs.metrics`` — the unified ``MetricsRegistry`` (counters / gauges /
  histograms with labels, snapshot/delta semantics, collectors sampling
  the kernel dispatch counters and per-store state).
* ``obs.trace`` — structured span tracing on measured + simulated clocks
  with a Chrome trace-event (Perfetto) exporter and validator; zero-cost
  when no tracer is installed.
* ``obs.explain`` — ``Ticket.explain()``: the per-query latency
  decomposition (queue wait vs service, scan modes, cache-tier outcome,
  build/demote walls charged), exact against the modeled schedule.
"""
from repro.obs import explain, metrics, trace  # noqa: F401
from repro.obs.metrics import (REGISTRY, MetricsRegistry, nearest_rank,  # noqa: F401
                               observe_flush, observe_job, observe_upload,
                               register_store)
from repro.obs.trace import (Tracer, install, uninstall,  # noqa: F401
                             validate_chrome_trace)
