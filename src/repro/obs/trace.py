"""Structured span tracing for the HAIL runtime, exported as Chrome
trace-event JSON (Perfetto-loadable).

Two clocks, two trace processes:

* **pid 1 "hail (measured wall)"** — real ``time.perf_counter`` sections:
  upload phases, flush lifecycle (result-cache probe, batching, plan,
  per-split dispatch, verify, cache fill, ticket finalize), adaptive
  builds, demotions, quarantine/repair instants, scrubber ticks.
* **pid 2 "cluster (simulated)"** — the deterministic simulated timeline:
  ``run_schedule`` task runs become per-node tracks, ``ServerFrontend``
  queries become per-tenant slices from arrival to modeled completion,
  and flow arrows (``s``/``t``/``f`` events keyed by ticket id) connect a
  query's slice to every scheduler task its answer depended on.

Tracing is OFF by default and ZERO-COST when off: every module-level hook
(`span`, ``instant``, ``complete_wall``, …) reads one global and returns a
shared no-op when no tracer is installed — no allocation, no branches in
jit'd code (the hooks live on the host side of every dispatch).  Install
with ``tracer = trace.install()``, export with ``tracer.export(path)``,
remove with ``trace.uninstall()``.

``validate_chrome_trace`` checks the exported object against the parts of
the Chrome trace-event contract Perfetto actually enforces: known phases,
numeric non-negative ``ts``, non-negative ``dur`` on ``X`` events, and
per-(pid, tid) ``B``/``E`` discipline (LIFO name matching, monotone
timestamps, no unclosed spans) — CI validates every uploaded trace with it.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Optional

PID_WALL = 1     # measured perf_counter sections
PID_SIM = 2      # simulated scheduler/frontend timeline

_VALID_PHASES = frozenset("BEXiIMstfCbne")


class Tracer:
    """Event buffer + clock anchor for one tracing session."""

    def __init__(self):
        self.t0 = time.perf_counter()      # epoch for the measured clock
        self.events: list[dict] = []
        self._tids: dict[tuple[int, str], int] = {}
        self._flow_seen: set[int] = set()
        for pid, name in ((PID_WALL, "hail (measured wall)"),
                          (PID_SIM, "cluster (simulated)")):
            self.events.append({"ph": "M", "pid": pid, "tid": 0, "ts": 0,
                                "name": "process_name",
                                "args": {"name": name}})

    # -- tracks -------------------------------------------------------------

    def _tid(self, pid: int, track: str) -> int:
        key = (pid, track)
        tid = self._tids.get(key)
        if tid is None:
            tid = sum(1 for k in self._tids if k[0] == pid) + 1
            self._tids[key] = tid
            self.events.append({"ph": "M", "pid": pid, "tid": tid, "ts": 0,
                                "name": "thread_name",
                                "args": {"name": track}})
        return tid

    def now_us(self) -> float:
        return (time.perf_counter() - self.t0) * 1e6

    # -- measured-wall events -----------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "hail", track: str = "main",
             args: Optional[dict] = None):
        """B/E span on the measured clock around a ``with`` body."""
        tid = self._tid(PID_WALL, track)
        ev = {"ph": "B", "pid": PID_WALL, "tid": tid, "name": name,
              "cat": cat, "ts": self.now_us()}
        if args:
            ev["args"] = dict(args)
        self.events.append(ev)
        try:
            yield self
        finally:
            self.events.append({"ph": "E", "pid": PID_WALL, "tid": tid,
                                "name": name, "cat": cat,
                                "ts": self.now_us()})

    def instant(self, name: str, *, cat: str = "hail", track: str = "main",
                args: Optional[dict] = None):
        ev = {"ph": "i", "pid": PID_WALL, "tid": self._tid(PID_WALL, track),
              "name": name, "cat": cat, "ts": self.now_us(), "s": "t"}
        if args:
            ev["args"] = dict(args)
        self.events.append(ev)

    def complete_wall(self, name: str, start_pc: float, dur_s: float, *,
                      cat: str = "hail", track: str = "main",
                      args: Optional[dict] = None):
        """X slice from a raw ``perf_counter`` stamp + duration — for
        async-dispatched work whose wall is only known after its barrier
        (per-split reads record their dispatch stamp, then emit here)."""
        ev = {"ph": "X", "pid": PID_WALL, "tid": self._tid(PID_WALL, track),
              "name": name, "cat": cat,
              "ts": max(0.0, (start_pc - self.t0) * 1e6),
              "dur": max(0.0, dur_s) * 1e6}
        if args:
            ev["args"] = dict(args)
        self.events.append(ev)

    # -- simulated-clock events ---------------------------------------------

    def complete_sim(self, name: str, start_s: float, dur_s: float, *,
                     cat: str = "sim", track: str = "timeline",
                     args: Optional[dict] = None):
        ev = {"ph": "X", "pid": PID_SIM, "tid": self._tid(PID_SIM, track),
              "name": name, "cat": cat, "ts": max(0.0, start_s) * 1e6,
              "dur": max(0.0, dur_s) * 1e6}
        if args:
            ev["args"] = dict(args)
        self.events.append(ev)

    def flow(self, ph: str, flow_id: int, ts_s: float, *, track: str,
             name: str = "query", cat: str = "sim"):
        """One flow-arrow endpoint (ph in s/t/f) on the simulated clock."""
        ev = {"ph": ph, "pid": PID_SIM, "tid": self._tid(PID_SIM, track),
              "name": name, "cat": cat, "id": int(flow_id),
              "ts": max(0.0, ts_s) * 1e6}
        if ph == "f":
            ev["bp"] = "e"
        elif ph == "s":
            self._flow_seen.add(int(flow_id))
        self.events.append(ev)

    def add_schedule(self, sched, tasks, *, base_s: float = 0.0,
                     label: str = "split"):
        """Render one ``run_schedule`` result onto the simulated timeline:
        every TaskRun becomes an X slice on its node's track, and each
        query id a task carries becomes a flow step (``t``) there — with
        the final carrying run emitting the flow end (``f``), so Perfetto
        draws an arrow chain from the query's arrival slice (the frontend
        emits the ``s`` start) through every split it waited on."""
        by_id = {t.task_id: t for t in tasks}
        completion = getattr(sched, "query_completion_s", {}) or {}
        for run in sorted(sched.runs, key=lambda r: r.start_s):
            task = by_id.get(run.task_id)
            track = f"node {run.node}"
            args = {"task": run.task_id, "speculative": run.speculative}
            qids = tuple(task.query_ids) if task is not None else ()
            if task is not None:
                args.update(n_queries=task.n_queries,
                            read_s=task.duration_s,
                            build_s=task.index_build_s,
                            rekey_s=task.rekey_s,
                            queries=list(qids))
            self.complete_sim(label, base_s + run.start_s,
                              run.end_s - run.start_s, track=track,
                              args=args)
            for qid in qids:
                ends_here = abs(completion.get(qid, -1.0) - run.end_s) < 1e-12
                if qid not in self._flow_seen:
                    self._flow_seen.add(qid)
                    self.flow("s", qid, base_s + run.start_s, track=track)
                if ends_here:
                    self.flow("f", qid, base_s + run.end_s, track=track)
                else:
                    self.flow("t", qid, base_s + run.start_s, track=track)

    # -- export -------------------------------------------------------------

    def export(self, path: Optional[str] = None) -> dict:
        trace = {"traceEvents": list(self.events),
                 "displayTimeUnit": "ms"}
        if path is not None:
            with open(path, "w") as f:
                json.dump(trace, f)
        return trace


# ---------------------------------------------------------------------------
# module-level hooks: one global read when tracing is off
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


class _NullSpan:
    """Shared no-op context manager — the entire cost of a disabled span."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


def install(tracer: Optional[Tracer] = None) -> Tracer:
    """Install (and return) the process-global tracer."""
    global _TRACER
    _TRACER = tracer if tracer is not None else Tracer()
    return _TRACER


def uninstall() -> Optional[Tracer]:
    """Remove the global tracer; returns it (export still works)."""
    global _TRACER
    t, _TRACER = _TRACER, None
    return t


def current() -> Optional[Tracer]:
    return _TRACER


def enabled() -> bool:
    return _TRACER is not None


def span(name: str, **kw):
    t = _TRACER
    return _NULL if t is None else t.span(name, **kw)


def instant(name: str, **kw):
    t = _TRACER
    if t is not None:
        t.instant(name, **kw)


def complete_wall(name: str, start_pc: float, dur_s: float, **kw):
    t = _TRACER
    if t is not None:
        t.complete_wall(name, start_pc, dur_s, **kw)


def complete_sim(name: str, start_s: float, dur_s: float, **kw):
    t = _TRACER
    if t is not None:
        t.complete_sim(name, start_s, dur_s, **kw)


def add_schedule(sched, tasks, **kw):
    t = _TRACER
    if t is not None:
        t.add_schedule(sched, tasks, **kw)


def flow(ph: str, flow_id: int, ts_s: float, **kw):
    t = _TRACER
    if t is not None:
        t.flow(ph, flow_id, ts_s, **kw)


# ---------------------------------------------------------------------------
# Chrome trace-event validation (the CI gate for exported traces)
# ---------------------------------------------------------------------------


def validate_chrome_trace(trace) -> list[str]:
    """Return a list of contract violations (empty == valid).

    Checks: top-level shape, known phases, numeric non-negative ``ts``,
    non-negative ``dur`` on X events, and per-(pid, tid) B/E discipline —
    every E matches the innermost open B by name, timestamps never run
    backwards within a track's B/E stream, and no span is left open.
    """
    errors: list[str] = []
    if isinstance(trace, dict):
        events = trace.get("traceEvents")
        if not isinstance(events, list):
            return ["traceEvents missing or not a list"]
    elif isinstance(trace, list):
        events = trace
    else:
        return [f"trace must be dict or list, got {type(trace).__name__}"]

    stacks: dict[tuple, list[str]] = {}
    last_ts: dict[tuple, float] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PHASES:
            errors.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph == "M":
            continue                       # metadata: no timing contract
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i} ({ev.get('name')!r}): bad ts {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur", 0)
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({ev.get('name')!r}): "
                              f"bad dur {dur!r}")
        if ph in ("B", "E"):
            key = (ev.get("pid"), ev.get("tid"))
            if ts < last_ts.get(key, 0.0) - 1e-9:
                errors.append(f"event {i} ({ev.get('name')!r}): ts not "
                              f"monotone on track {key}")
            last_ts[key] = max(last_ts.get(key, 0.0), float(ts))
            stack = stacks.setdefault(key, [])
            if ph == "B":
                stack.append(ev.get("name"))
            else:
                if not stack:
                    errors.append(f"event {i}: E {ev.get('name')!r} "
                                  f"without open B on track {key}")
                elif stack[-1] != ev.get("name"):
                    errors.append(f"event {i}: E {ev.get('name')!r} does "
                                  f"not match open B {stack[-1]!r}")
                    stack.pop()
                else:
                    stack.pop()
    for key, stack in stacks.items():
        if stack:
            errors.append(f"track {key}: unclosed spans {stack}")
    return errors
