"""Unified metrics registry: typed counters/gauges/histograms with labels.

Before this module the repro's evidence lived on four ad-hoc surfaces —
``ops.reader_stats`` Counters, ``JobStats``/``FlushStats`` dataclasses,
the governor's ``AccessLog`` and the scrubber's ``ScrubStats`` — each with
its own hand-rolled before/after dict diff in tests and benchmarks.  The
``MetricsRegistry`` makes them one self-describing surface:

* **Instruments**: ``Counter`` (monotone), ``Gauge`` (sampled level) and
  ``Histogram`` (count/sum/min/max + nearest-rank percentiles), each keyed
  by name + a label set (tenant, column, replica, scan-mode, cache-tier —
  whatever the call site knows).
* **Collectors**: pull adapters registered on the registry and run at
  ``snapshot()`` time.  The reader-stats collector (installed on the
  default ``REGISTRY`` at import) samples every ``ops.DISPATCH_COUNTS`` /
  ``TRACE_COUNTS`` key — per-column attribution like
  ``index_scan_blocks[visitDate]`` becomes a ``column`` label —  so a
  registry snapshot always reflects the live kernel counters.
  ``register_store`` adds governor heat, demotion totals, cache tiers and
  the scrubber cursor for one store.
* **Snapshot/delta**: ``snapshot()`` returns a flat ``{series: value}``
  dict; ``delta(before)`` subtracts two snapshots — the one idiom that
  replaces every hand-rolled ``h0 = cache.stats.hits ... hits - h0`` diff,
  and what the ``bench_*`` drivers now write BENCH_kernels.json from.
* **Observers**: ``observe_job`` / ``observe_flush`` / ``observe_upload``
  fold the existing stats dataclasses into first-class instruments (walls
  into histograms, counts into counters) — called by ``run_job``,
  ``HailServer.flush`` and the upload pipelines.

``nearest_rank`` is the pinned percentile semantics shared with
``ServerFrontend.percentile_latency`` (see its doctest).
"""
from __future__ import annotations

import math
import re
from typing import Callable, Optional


def nearest_rank(values, p: float) -> float:
    """Nearest-rank percentile: the smallest element with at least
    ``p``% of the sample at or below it — ``sorted[ceil(p/100*N)] - 1``
    (1-indexed), never interpolated, so small-N guards are not sensitive
    to interpolation off-by-ones and every returned value is an actually
    observed sample.

    >>> nearest_rank([10.0, 20.0, 30.0, 40.0], 50)
    20.0
    >>> nearest_rank([10.0, 20.0, 30.0, 40.0], 99)
    40.0
    >>> nearest_rank([40.0, 10.0, 30.0, 20.0], 25)
    10.0
    >>> nearest_rank([7.5], 1)
    7.5
    >>> nearest_rank([1.0, 2.0], 0)
    1.0
    """
    vals = sorted(values)
    if not vals:
        raise ValueError("nearest_rank of an empty sample")
    k = max(1, math.ceil(float(p) / 100.0 * len(vals)))
    return float(vals[min(k, len(vals)) - 1])


def _series(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Instrument:
    __slots__ = ("name", "labels", "series")
    kind = "instrument"

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self.series = _series(name, labels)


class Counter(Instrument):
    """Monotone count — ``inc`` only."""
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self.value = 0.0

    def inc(self, value: float = 1.0):
        if value < 0:
            raise ValueError(f"counter {self.series}: negative inc {value}")
        self.value += value


class Gauge(Instrument):
    """Sampled level — ``set`` replaces; collectors use these to mirror
    externally-owned counters (delta semantics still work because the
    snapshot samples the source each time)."""
    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self.value = 0.0

    def set(self, value: float):
        self.value = float(value)


class Histogram(Instrument):
    """Distribution: count/sum/min/max plus nearest-rank percentiles over
    the retained samples (these are simulation-scale series — retention is
    exact, not sketched)."""
    __slots__ = ("count", "total", "vmin", "vmax", "samples")
    kind = "histogram"

    def __init__(self, name, labels):
        super().__init__(name, labels)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.samples: list[float] = []

    def observe(self, value: float):
        v = float(value)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        self.samples.append(v)

    def percentile(self, p: float) -> float:
        return nearest_rank(self.samples, p)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Instrument store + collector runner with snapshot/delta semantics."""

    def __init__(self):
        self._instruments: dict[str, Instrument] = {}
        self._collectors: list[Callable[["MetricsRegistry"], None]] = []

    # -- instrument access (get-or-create; kind clashes are bugs) -----------

    def _get(self, cls, name: str, labels: dict):
        key = _series(name, labels)
        inst = self._instruments.get(key)
        if inst is None:
            inst = self._instruments[key] = cls(name, labels)
        elif not isinstance(inst, cls):
            raise TypeError(f"{key} already registered as {inst.kind}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def inc(self, name: str, value: float = 1.0, **labels):
        self.counter(name, **labels).inc(value)

    def observe(self, name: str, value: float, **labels):
        self.histogram(name, **labels).observe(value)

    def get(self, series: str) -> Optional[Instrument]:
        return self._instruments.get(series)

    def instruments(self) -> list[Instrument]:
        return list(self._instruments.values())

    # -- collectors ---------------------------------------------------------

    def register_collector(self, fn: Callable[["MetricsRegistry"], None]):
        self._collectors.append(fn)
        return fn

    def unregister_collector(self, fn):
        self._collectors = [c for c in self._collectors if c is not fn]

    def collect(self):
        for fn in list(self._collectors):
            fn(self)

    # -- snapshot / delta ---------------------------------------------------

    def snapshot(self, collect: bool = True) -> dict[str, float]:
        """Flat ``{series: value}``; histograms expand to ``.count``,
        ``.sum``, ``.min``, ``.max`` series."""
        if collect:
            self.collect()
        out: dict[str, float] = {}
        for inst in self._instruments.values():
            if isinstance(inst, Histogram):
                out[_series(inst.name + ".count", inst.labels)] = \
                    float(inst.count)
                out[_series(inst.name + ".sum", inst.labels)] = inst.total
                if inst.count:
                    out[_series(inst.name + ".min", inst.labels)] = inst.vmin
                    out[_series(inst.name + ".max", inst.labels)] = inst.vmax
            else:
                out[inst.series] = inst.value
        return out

    def delta(self, before: dict[str, float],
              after: Optional[dict[str, float]] = None,
              collect: bool = True) -> dict[str, float]:
        """``after - before`` per series (``after`` defaults to a fresh
        snapshot); series absent from ``before`` diff against 0."""
        if after is None:
            after = self.snapshot(collect=collect)
        return {k: v - before.get(k, 0.0) for k, v in after.items()}

    def reset(self):
        self._instruments.clear()


REGISTRY = MetricsRegistry()


def snapshot(collect: bool = True) -> dict[str, float]:
    return REGISTRY.snapshot(collect=collect)


def delta(before: dict[str, float], **kw) -> dict[str, float]:
    return REGISTRY.delta(before, **kw)


# ---------------------------------------------------------------------------
# collectors: reader-stats counters and per-store state
# ---------------------------------------------------------------------------

_READER_KEY = re.compile(r"^(?P<name>[^\[\]]+)(?:\[(?P<column>[^\]]+)\])?$")


def parse_reader_key(key: str) -> tuple[str, dict]:
    """``index_scan_blocks[visitDate]`` -> (``index_scan_blocks``,
    ``{"column": "visitDate"}``); plain keys get no labels."""
    m = _READER_KEY.match(key)
    if m is None or m.group("column") is None:
        return key, {}
    return m.group("name"), {"column": m.group("column")}


def reader_stats_collector(reg: MetricsRegistry):
    """Sample every live ``ops`` dispatch/trace counter into the registry
    (gauges, so ``reset_stats``/``stats_scope`` swaps stay coherent —
    a snapshot always mirrors the innermost scope's counters, and a
    counter that vanished from the source reads 0, never a stale value)."""
    from repro.kernels import ops
    stats = ops.reader_stats()
    seen: set[str] = set()
    for key, v in stats["dispatches"].items():
        name, labels = parse_reader_key(key)
        g = reg.gauge(f"reader.{name}", **labels)
        g.set(v)
        seen.add(g.series)
    for key, v in stats["traces"].items():
        g = reg.gauge(f"reader.traces.{key}")
        g.set(v)
        seen.add(g.series)
    for inst in reg.instruments():
        if (isinstance(inst, Gauge) and inst.series not in seen
                and inst.name.startswith("reader.")):
            inst.set(0.0)


REGISTRY.register_collector(reader_stats_collector)


def register_store(store, registry: Optional[MetricsRegistry] = None):
    """Register a per-store collector: governor heat/demotions, both cache
    tiers and the scrubber cursor become sampled gauges.  Returns the
    collector (pass to ``unregister_collector`` when the store is done)."""
    reg = registry if registry is not None else REGISTRY

    def _collect(r: MetricsRegistry):
        log = store.access_log
        if log is not None:
            for (rid, col), rec in log.counts.items():
                r.gauge("governor.heat", replica=rid, column=col).set(
                    rec.hits + rec.misses)
                r.gauge("governor.miss_heat", replica=rid, column=col).set(
                    rec.misses)
                r.gauge("governor.last_used", replica=rid, column=col).set(
                    rec.last_used)
            r.gauge("governor.job_clock").set(log.job_clock)
        gov = store.governor
        if gov is not None:
            r.gauge("governor.blocks_demoted").set(gov.blocks_demoted_total)
            r.gauge("governor.demotions").set(len(gov.events))
        if store.block_cache is not None:
            st = store.block_cache.stats
            r.gauge("cache.hits", tier="block").set(st.hits)
            r.gauge("cache.misses", tier="block").set(st.misses)
            r.gauge("cache.evictions", tier="block").set(st.evictions)
            r.gauge("cache.resident_bytes", tier="block").set(
                st.resident_bytes)
        if store.result_cache is not None:
            st = store.result_cache.stats
            r.gauge("cache.hits", tier="result").set(st.hits)
            r.gauge("cache.misses", tier="result").set(st.misses)
        if store.scrubber is not None:
            sc = store.scrubber
            r.gauge("scrubber.cursor").set(sc._cursor)
            r.gauge("scrubber.ticks").set(sc.stats.ticks)
            r.gauge("scrubber.blocks_verified").set(sc.stats.blocks_verified)
            r.gauge("scrubber.blocks_repaired").set(sc.stats.blocks_repaired)
        r.gauge("store.version").set(store.version)
        r.gauge("store.total_indexed_blocks").set(
            store.total_indexed_blocks() if store.layout == "pax" else 0)
        if store.layout == "pax":
            r.gauge("store.live_replicas").set(len(store.live_replica_ids()))

    reg.register_collector(_collect)
    return _collect


# ---------------------------------------------------------------------------
# observers: fold the existing stats dataclasses into instruments
# ---------------------------------------------------------------------------


def observe_job(stats, registry: Optional[MetricsRegistry] = None, **labels):
    """Fold one ``JobStats`` into the registry (called by ``run_job``)."""
    reg = registry if registry is not None else REGISTRY
    reg.inc("job.jobs", 1, **labels)
    reg.inc("job.tasks", stats.n_tasks, **labels)
    reg.inc("job.bytes_read", stats.bytes_read, **labels)
    reg.inc("job.blocks_indexed", stats.blocks_indexed, **labels)
    reg.inc("job.blocks_demoted", stats.blocks_demoted, **labels)
    reg.inc("job.blocks_quarantined", stats.blocks_quarantined, **labels)
    reg.inc("job.corrupt_retries", stats.corrupt_retries, **labels)
    reg.inc("job.rescheduled_tasks", stats.rescheduled_tasks, **labels)
    reg.inc("job.blocks", stats.full_scan_blocks,
            scan_mode="full", **labels)
    reg.observe("job.wall_s", stats.map_compute_s, **labels)
    reg.observe("job.modeled_s", stats.modeled_s, **labels)
    reg.observe("job.build_s", stats.index_build_s, **labels)
    reg.observe("job.rekey_s", stats.rekey_s, **labels)
    reg.observe("job.scrub_s", stats.scrub_s, **labels)
    for s in stats.split_s:
        reg.observe("job.split_s", s, **labels)


def observe_flush(stats, registry: Optional[MetricsRegistry] = None,
                  tenants=(), **labels):
    """Fold one ``FlushStats`` into the registry (called by ``flush``).
    ``tenants``: the flush's tickets' tenants, counted per label."""
    reg = registry if registry is not None else REGISTRY
    reg.inc("flush.flushes", 1, **labels)
    reg.inc("flush.queries", stats.n_queries, **labels)
    reg.inc("flush.batches", stats.n_batches, **labels)
    reg.inc("flush.splits", stats.n_splits, **labels)
    reg.inc("flush.bytes_read", stats.bytes_read, **labels)
    reg.inc("flush.blocks_indexed", stats.blocks_indexed, **labels)
    reg.inc("flush.blocks_demoted", stats.blocks_demoted, **labels)
    reg.inc("flush.blocks_quarantined", stats.blocks_quarantined, **labels)
    reg.inc("flush.corrupt_retries", stats.corrupt_retries, **labels)
    reg.inc("flush.failed_queries", len(stats.failed_queries), **labels)
    reg.inc("flush.cache_hits", stats.cache_hits, tier="block", **labels)
    reg.inc("flush.cache_misses", stats.cache_misses, tier="block", **labels)
    reg.inc("flush.cache_hits", stats.result_cache_hits,
            tier="result", **labels)
    reg.inc("flush.cache_misses", stats.result_cache_misses,
            tier="result", **labels)
    for tenant in tenants:
        reg.inc("flush.tenant_queries", 1, tenant=tenant, **labels)
    reg.observe("flush.wall_s", stats.wall_s, **labels)
    reg.observe("flush.modeled_s", stats.modeled_s, **labels)
    reg.observe("flush.scrub_s", stats.scrub_s, **labels)
    for s in stats.split_s:
        reg.observe("flush.split_s", s, **labels)
    for done in stats.query_done_s.values():
        reg.observe("flush.query_done_s", done, **labels)


def observe_upload(kind: str, stats,
                   registry: Optional[MetricsRegistry] = None):
    """Fold one ``UploadStats`` into the registry (upload pipelines)."""
    reg = registry if registry is not None else REGISTRY
    reg.inc("upload.uploads", 1, kind=kind)
    reg.inc("upload.ascii_bytes", stats.ascii_bytes, kind=kind)
    reg.inc("upload.written_bytes", stats.written_bytes, kind=kind)
    reg.inc("upload.extra_read_bytes", stats.extra_read_bytes, kind=kind)
    reg.inc("upload.n_indexes", stats.n_indexes, kind=kind)
    reg.observe("upload.wall_s", stats.wall_s, kind=kind)
    for phase, wall in stats.phases.items():
        reg.observe("upload.phase_s", wall, kind=kind, phase=phase)
