"""Per-query EXPLAIN: reconstruct why a served query took the time it did.

After PR 8 latency became per-query (``FlushStats.query_done_s`` measured,
``ScheduleResult.query_completion_s`` modeled), but the *decomposition* —
queue wait vs service, which flush trigger fired, index vs full-scan blocks
per split, cache-tier outcome, retries survived, build/demotion walls
charged — was smeared across FlushStats fields and reader counters.

``HailServer.flush`` attaches one shared ``FlushExplain`` context to every
ticket it answers; ``Ticket.explain()`` resolves it lazily into an
``ExplainRecord``.  The modeled decomposition is EXACT by construction:
a ticket's modeled completion is the end of the last scheduler task run
carrying its id, and that run's end decomposes as

    completion = sched_wait (run start)
               + speed-scaled (read + adaptive build + demotion rekey)

so ``accounted_s`` equals ``query_completion_s`` to float precision and
``accounted_fraction`` is 1.0 — comfortably over the >= 95% acceptance
bar — for cold queries, quarantine survivors, and (by the zero-denominator
convention: a result-cache hit is carried by no task and completes at
offset 0) cache hits alike.  The ``ServerFrontend`` enriches the context
with the simulated arrival, flush trigger and observed latency, turning
``sched_wait`` into true queue wait against the SLO clock.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class SplitShare:
    """One scheduler task run this query's answer depended on, with its
    modeled wall decomposed into what the split actually did."""
    task_id: int
    node: int
    start_s: float
    end_s: float
    read_s: float          # speed-scaled shared-scan read wall
    build_s: float         # adaptive index build piggybacked on this split
    rekey_s: float         # governor demotion (un-sort) charged here
    batch_width: int       # queries sharing the split's one fused dispatch
    index_blocks: int = 0  # split's blocks served by the clustered index
    full_blocks: int = 0   # split's blocks that had to full-scan


@dataclasses.dataclass
class ExplainRecord:
    ticket_id: int
    tenant: str
    query: str
    status: str
    outcome: str            # result_hit | warm | mixed | cold | failed
    trigger: str            # manual | window | batch_full | drain
    completion_s: float     # modeled end-to-end (query_completion_s)
    sched_wait_s: float     # modeled wait before its last carrying run
    read_s: float           # service decomposition of that run
    build_s: float
    rekey_s: float
    accounted_s: float      # sched_wait + read + build + rekey
    accounted_fraction: float
    splits: list            # every carrying SplitShare, start order
    index_blocks: int       # per-query scan-mode totals across its splits
    full_blocks: int
    done_wall_s: Optional[float]    # measured stream-back offset (flush t0)
    queue_wait_s: Optional[float]   # sim: flush trigger - arrival (frontend)
    latency_s: Optional[float]      # sim: completion - arrival (frontend)
    retries_survived: int           # flush-level corruption re-plans
    quarantined: int                # flush-level blocks quarantined
    flush: dict                     # flush-level summary (caches, walls)
    error: Optional[str] = None

    def render(self) -> str:
        lines = [f"query #{self.ticket_id} ({self.tenant}): {self.query}",
                 f"  status={self.status}  outcome={self.outcome}  "
                 f"trigger={self.trigger}"]
        if self.latency_s is not None:
            lines.append(f"  latency          {self.latency_s:.3f}s  "
                         f"(queue wait {self.queue_wait_s:.3f}s + "
                         f"modeled service {self.completion_s:.3f}s)")
        lines.append(f"  modeled e2e      {self.completion_s:.4f}s  "
                     f"accounted {self.accounted_s:.4f}s "
                     f"({self.accounted_fraction:.1%})")
        lines.append(f"    sched wait     {self.sched_wait_s:.4f}s")
        lines.append(f"    shared read    {self.read_s:.4f}s")
        if self.build_s:
            lines.append(f"    adaptive build {self.build_s:.4f}s")
        if self.rekey_s:
            lines.append(f"    demote rekey   {self.rekey_s:.4f}s")
        lines.append(f"  scan mode        {self.index_blocks} index / "
                     f"{self.full_blocks} full-scan blocks "
                     f"over {len(self.splits)} splits")
        if self.done_wall_s is not None:
            lines.append(f"  streamed back    {self.done_wall_s * 1e3:.2f}ms"
                         f" after flush start (measured)")
        if self.retries_survived or self.quarantined:
            lines.append(f"  survived         {self.quarantined} quarantines"
                         f", {self.retries_survived} re-plan retries "
                         f"(flush-level)")
        fl = self.flush
        lines.append(f"  flush            {fl.get('n_queries', 0)} queries /"
                     f" {fl.get('n_batches', 0)} batches /"
                     f" {fl.get('n_splits', 0)} splits; block cache"
                     f" {fl.get('cache_hits', 0)}h/{fl.get('cache_misses', 0)}m;"
                     f" result cache {fl.get('result_cache_hits', 0)}h/"
                     f"{fl.get('result_cache_misses', 0)}m")
        if self.error:
            lines.append(f"  error            {self.error}")
        return "\n".join(lines)

    def __str__(self) -> str:
        return self.render()


class FlushExplain:
    """Shared per-flush context: owns the FlushStats and lazily bridges
    them through the scheduler exactly once (the ServerFrontend provides
    its own schedule instead, so explain agrees with the latency it
    reported).  One instance is attached to every ticket of a flush."""

    def __init__(self, stats, cluster_model):
        self.stats = stats
        self.cluster = cluster_model
        self.trigger = "manual"
        self.start_s = 0.0
        self.arrival_s: dict[int, float] = {}
        self.latency_s: dict[int, float] = {}
        self._tasks = None
        self._sched = None

    def provide_schedule(self, sched, tasks):
        self._sched, self._tasks = sched, tasks

    def schedule(self):
        if self._sched is None:
            from repro.runtime.cluster import SimulatedCluster
            from repro.runtime.jobserver import flush_tasks
            from repro.runtime.scheduler import run_schedule
            self._tasks = flush_tasks(self.stats)
            self._sched = run_schedule(
                self._tasks,
                SimulatedCluster(n_nodes=self.cluster.n_nodes,
                                 map_slots=self.cluster.map_slots),
                spec_factor=None)
        return self._sched, self._tasks


def explain_ticket(ticket) -> ExplainRecord:
    """Build the ExplainRecord for one flushed ticket (``Ticket.explain``)."""
    ctx: Optional[FlushExplain] = getattr(ticket, "explain_ctx", None)
    if ctx is None:
        raise RuntimeError(
            f"ticket {ticket.ticket_id} has not been flushed yet — "
            f"explain() reconstructs a completed flush")
    stats = ctx.stats
    sched, tasks = ctx.schedule()
    tid = ticket.ticket_id
    completion = float(sched.query_completion_s.get(tid, 0.0))

    by_id = {t.task_id: t for t in tasks}
    shares: list[SplitShare] = []
    scan_modes = list(getattr(stats, "split_scan_modes", ()))
    for run in sorted(sched.runs, key=lambda r: r.start_s):
        task = by_id.get(run.task_id)
        if task is None or tid not in task.query_ids:
            continue
        work = task.duration_s + task.index_build_s + task.rekey_s
        scale = (run.end_s - run.start_s) / work if work > 0 else 0.0
        n_idx = n_full = 0
        if run.task_id < len(scan_modes):
            n_idx, n_full = scan_modes[run.task_id]
        shares.append(SplitShare(
            task_id=run.task_id, node=run.node,
            start_s=run.start_s, end_s=run.end_s,
            read_s=task.duration_s * scale,
            build_s=task.index_build_s * scale,
            rekey_s=task.rekey_s * scale,
            batch_width=task.n_queries,
            index_blocks=n_idx, full_blocks=n_full))

    # the EXACT decomposition: completion == last carrying run's end ==
    # its start (scheduler wait) + its speed-scaled service components
    if shares:
        last = max(shares, key=lambda s: s.end_s)
        sched_wait = last.start_s
        read_s, build_s, rekey_s = last.read_s, last.build_s, last.rekey_s
    else:
        sched_wait = read_s = build_s = rekey_s = 0.0
    accounted = sched_wait + read_s + build_s + rekey_s
    fraction = accounted / completion if completion > 0 else 1.0

    result = ticket.result
    if ticket.status == "failed":
        outcome = "failed"
    elif result is not None and result.from_cache:
        outcome = "result_hit"
    elif stats.cache_hits > 0 and stats.cache_misses == 0:
        outcome = "warm"          # every block-gather this flush was cached
    elif stats.cache_hits > 0:
        outcome = "mixed"
    else:
        outcome = "cold"

    arrival = ctx.arrival_s.get(tid)
    queue_wait = (ctx.start_s - arrival) if arrival is not None else None
    return ExplainRecord(
        ticket_id=tid, tenant=ticket.tenant, query=repr(ticket.query),
        status=ticket.status, outcome=outcome, trigger=ctx.trigger,
        completion_s=completion, sched_wait_s=sched_wait,
        read_s=read_s, build_s=build_s, rekey_s=rekey_s,
        accounted_s=accounted, accounted_fraction=fraction,
        splits=shares,
        index_blocks=sum(s.index_blocks for s in shares),
        full_blocks=sum(s.full_blocks for s in shares),
        done_wall_s=stats.query_done_s.get(tid),
        queue_wait_s=queue_wait,
        latency_s=ctx.latency_s.get(tid),
        retries_survived=stats.corrupt_retries,
        quarantined=stats.blocks_quarantined,
        flush={"n_queries": stats.n_queries, "n_batches": stats.n_batches,
               "n_splits": stats.n_splits, "wall_s": stats.wall_s,
               "modeled_s": stats.modeled_s,
               "cache_hits": stats.cache_hits,
               "cache_misses": stats.cache_misses,
               "result_cache_hits": stats.result_cache_hits,
               "result_cache_misses": stats.result_cache_misses},
        error=ticket.error)
