"""Layer-stack pattern machinery.

A model stack is a repeated *pattern* of layer configs (e.g. gemma3's
[local, local, local, local, local, global]), executed as ``lax.scan`` over
pattern *groups* with group-stacked parameters — HLO stays small enough that a
512-device GSPMD compile takes seconds.  A partial ``tail`` runs unscanned
after the groups (gemma3-4b: 34 = 5*6 + 4).  ``kind='shared'`` positions reuse
a single shared parameter set (zamba2's shared attention block) while keeping
a *per-occurrence* KV cache.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerCfg, StackCfg
from repro.dist.sharding import TensorSpec, is_spec, map_specs, tspec
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models.attention import (attn_cache_specs, attn_specs,
                                    cache_len_for, cross_cache_specs)
from repro.models.common import rmsnorm, rmsnorm_spec
from repro.models.mlp import mlp, mlp_specs
from repro.models.moe import moe, moe_specs

Pytree = Any


# ---------------------------------------------------------------------------
# Per-layer specs / caches / apply
# ---------------------------------------------------------------------------


def layer_specs(lc: LayerCfg, d_model: int) -> dict[str, Any]:
    if lc.kind == "shared":
        return {}
    if lc.kind == "attn_mlp":
        s: dict[str, Any] = {
            "ln1": rmsnorm_spec(d_model),
            "attn": attn_specs(lc.attn, d_model),
            "ln2": rmsnorm_spec(d_model),
        }
        if lc.attn.cross:
            s["ln_x"] = rmsnorm_spec(d_model)
            s["xattn"] = attn_specs(lc.attn, d_model)
        s["ffn"] = moe_specs(lc.moe, d_model) if lc.moe else mlp_specs(lc.mlp, d_model)
        return s
    if lc.kind == "mamba1":
        return {"ln": rmsnorm_spec(d_model), "ssm": mamba_mod.mamba1_specs(lc.ssm, d_model)}
    if lc.kind == "mamba2":
        return {"ln": rmsnorm_spec(d_model), "ssm": mamba_mod.mamba2_specs(lc.ssm, d_model)}
    raise ValueError(lc.kind)


def layer_cache_specs(lc: LayerCfg, shared: Optional[LayerCfg], d_model: int,
                      batch: int, seq_len: int, enc_len: int | None,
                      dtype=jnp.bfloat16) -> dict[str, Any]:
    eff = shared if lc.kind == "shared" else lc
    if eff.kind == "attn_mlp":
        c = {"self": attn_cache_specs(eff.attn, batch,
                                      cache_len_for(eff.attn, seq_len), dtype)}
        if eff.attn.cross:
            c["cross"] = cross_cache_specs(eff.attn, batch, enc_len, dtype)
        return c
    if eff.kind == "mamba1":
        return {"ssm": mamba_mod.mamba1_cache_specs(eff.ssm, d_model, batch, dtype)}
    if eff.kind == "mamba2":
        return {"ssm": mamba_mod.mamba2_cache_specs(eff.ssm, d_model, batch, dtype)}
    raise ValueError(eff.kind)


def apply_layer(lc: LayerCfg, shared_cfg: Optional[LayerCfg], params, x, *,
                mode: str, cache, aux: dict, eps: float):
    eff = shared_cfg if lc.kind == "shared" else lc
    new_cache: dict[str, Any] = {}
    if eff.kind == "attn_mlp":
        h = rmsnorm(x, params["ln1"], eps)
        # self-attention: whisper-style cross layers use positions from aux
        a_cfg = eff.attn
        self_cfg = a_cfg if not a_cfg.cross else _no_cross(a_cfg)
        a, c_self = attn_mod.attention(
            params["attn"], h, self_cfg, positions=aux["positions"], mode=mode,
            cache=cache.get("self") if cache else None,
            cache_len=aux.get("cache_len"))
        x = x + a
        if c_self is not None:
            new_cache["self"] = c_self
        if a_cfg.cross:
            h = rmsnorm(x, params["ln_x"], eps)
            a, c_cross = attn_mod.attention(
                params["xattn"], h, a_cfg, positions=None, mode=mode,
                cache=cache.get("cross") if cache else None, enc_kv=aux.get("enc"))
            x = x + a
            if c_cross is not None:
                new_cache["cross"] = c_cross
        h = rmsnorm(x, params["ln2"], eps)
        f = moe(params["ffn"], h, eff.moe) if eff.moe else mlp(params["ffn"], h, eff.mlp)
        x = x + f
        return x, (new_cache or None)
    if eff.kind in ("mamba1", "mamba2"):
        h = rmsnorm(x, params["ln"], eps)
        fn = mamba_mod.mamba1 if eff.kind == "mamba1" else mamba_mod.mamba2
        y, c = fn(params["ssm"], h, eff.ssm, mode=mode,
                  cache=cache.get("ssm") if cache else None)
        x = x + y
        return x, ({"ssm": c} if c is not None else None)
    raise ValueError(eff.kind)


def _no_cross(a_cfg):
    import dataclasses
    return dataclasses.replace(a_cfg, cross=False)


# ---------------------------------------------------------------------------
# Stack-level specs
# ---------------------------------------------------------------------------


def _stack_tree(tree: Pytree, n: int) -> Pytree:
    return map_specs(
        lambda s: TensorSpec((n,) + s.shape, ("layers",) + s.axes, s.dtype,
                             s.init, s.scale), tree)


def stack_specs(sc: StackCfg, d_model: int) -> dict[str, Any]:
    out: dict[str, Any] = {}
    group = {f"p{i}": layer_specs(lc, d_model) for i, lc in enumerate(sc.pattern)}
    group = {k: v for k, v in group.items() if v}
    if sc.n_groups > 0 and group:
        out["groups"] = _stack_tree(group, sc.n_groups)
    if sc.tail:
        out["tail"] = {f"t{i}": layer_specs(lc, d_model)
                       for i, lc in enumerate(sc.tail)}
        out["tail"] = {k: v for k, v in out["tail"].items() if v}
    if sc.shared is not None:
        out["shared"] = layer_specs(sc.shared, d_model)
    return out


def stack_cache_specs(sc: StackCfg, d_model: int, batch: int, seq_len: int,
                      enc_len: int | None, dtype=jnp.bfloat16) -> dict[str, Any]:
    out: dict[str, Any] = {}
    group = {f"p{i}": layer_cache_specs(lc, sc.shared, d_model, batch, seq_len,
                                        enc_len, dtype)
             for i, lc in enumerate(sc.pattern)}
    if sc.n_groups > 0:
        out["groups"] = _stack_tree(group, sc.n_groups)
    if sc.tail:
        out["tail"] = {f"t{i}": layer_cache_specs(lc, sc.shared, d_model, batch,
                                                  seq_len, enc_len, dtype)
                       for i, lc in enumerate(sc.tail)}
    return out


# ---------------------------------------------------------------------------
# Stack apply
# ---------------------------------------------------------------------------


def apply_stack(params, x, sc: StackCfg, *, mode: str, cache, aux: dict,
                eps: float, remat: str = "none"):
    """Returns (x, new_cache_or_None)."""
    shared_params = params.get("shared")

    def group_body(x, gp, gc):
        new_c: dict[str, Any] = {}
        for i, lc in enumerate(sc.pattern):
            key = f"p{i}"
            p = shared_params if lc.kind == "shared" else gp[key]
            c = gc.get(key) if gc is not None else None
            x, nc = apply_layer(lc, sc.shared, p, x, mode=mode, cache=c,
                                aux=aux, eps=eps)
            if nc is not None:
                new_c[key] = nc
        return x, new_c

    if remat == "full" and mode == "train":
        group_body = jax.checkpoint(group_body, static_argnums=())
    elif remat == "dots" and mode == "train":
        group_body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    new_cache: dict[str, Any] = {}
    if sc.n_groups > 0:
        gp_all = params["groups"]
        gc_all = cache.get("groups") if cache is not None else None

        if gc_all is not None:
            def body(x, xs):
                gp, gc = xs
                return group_body(x, gp, gc)
            x, caches = jax.lax.scan(body, x, (gp_all, gc_all))
        else:
            def body(x, gp):
                return group_body(x, gp, None)
            x, caches = jax.lax.scan(body, x, gp_all)
        if mode in ("prefill", "decode"):
            new_cache["groups"] = caches

    for i, lc in enumerate(sc.tail):
        key = f"t{i}"
        p = shared_params if lc.kind == "shared" else params["tail"][key]
        c = (cache.get("tail", {}) or {}).get(key) if cache is not None else None
        x, nc = apply_layer(lc, sc.shared, p, x, mode=mode, cache=c, aux=aux,
                            eps=eps)
        if nc is not None:
            new_cache.setdefault("tail", {})[key] = nc

    return x, (new_cache if mode in ("prefill", "decode") else None)
