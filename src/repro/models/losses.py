"""Losses: standard softmax cross-entropy + a vocab-memory-friendly chunked
variant (computes per-sequence-chunk logits inside a scan so the full
(B, T, V) tensor is never materialized — a §Perf memory-term lever for the
262k-vocab archs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def xent(logits: jax.Array, labels: jax.Array, mask=None):
    """logits (B,T,V) f32, labels (B,T) int32 -> scalar mean nll."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_xent(x: jax.Array, head: jax.Array, labels: jax.Array,
                 n_chunks: int = 8, mask=None):
    """x (B,T,D) final hidden states, head (D,V). Scans over T chunks."""
    b, t, d = x.shape
    assert t % n_chunks == 0
    tc = t // n_chunks
    xs = x.reshape(b, n_chunks, tc, d).swapaxes(0, 1)
    ls = labels.reshape(b, n_chunks, tc).swapaxes(0, 1)
    if mask is None:
        ms = jnp.ones((n_chunks, b, tc), jnp.float32)
    else:
        ms = mask.reshape(b, n_chunks, tc).swapaxes(0, 1).astype(jnp.float32)

    def body(acc, xs_):
        xc, lc, mc = xs_

        @jax.checkpoint
        def inner(xc, lc, mc):
            logits = jnp.einsum("btd,dv->btv", xc, head.astype(xc.dtype))
            logits = logits.astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            return ((logz - gold) * mc).sum(), mc.sum()

        s, m = inner(xc, lc, mc)
        return (acc[0] + s, acc[1] + m), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
