"""GQA attention: full/sliding-window masks, chunked online-softmax prefill,
KV caches (full-length and ring/window caches for long-context decode).

Cache layout per layer: {"k": (B, S, KV, Dh), "v": (B, S, KV, Dh),
"pos": (B, S) int32 absolute positions (-1 = empty)}.  A *ring* cache is the
same structure with S = window; slot = pos % window.  Keys are stored
post-RoPE (absolute rotary), the standard serving convention.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnCfg
from repro.dist.sharding import TensorSpec, constrain, tspec
from repro.models.common import apply_rope, rmsnorm, rmsnorm_spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter / cache specs
# ---------------------------------------------------------------------------


def attn_specs(cfg: AttnCfg, d_model: int) -> dict[str, TensorSpec]:
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.head_dim
    s = {
        "wq": tspec((d_model, h, dh), ("embed", "heads", "head_dim")),
        "wk": tspec((d_model, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": tspec((d_model, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": tspec((h, dh, d_model), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = tspec((dh,), ("head_dim",), init="ones")
        s["k_norm"] = tspec((dh,), ("head_dim",), init="ones")
    return s


def attn_cache_specs(cfg: AttnCfg, batch: int, cache_len: int,
                     dtype=jnp.bfloat16) -> dict[str, TensorSpec]:
    """cache_len should already account for ring caches (= min(S, window))."""
    kv, dh = cfg.n_kv, cfg.head_dim
    return {
        "k": tspec((batch, cache_len, kv, dh), ("batch", "kv_seq", "act_kv_heads", "head_dim"), dtype, init="zeros"),
        "v": tspec((batch, cache_len, kv, dh), ("batch", "kv_seq", "act_kv_heads", "head_dim"), dtype, init="zeros"),
        "pos": tspec((batch, cache_len), ("batch", "kv_seq"), jnp.int32, init="zeros"),
    }


def cache_len_for(cfg: AttnCfg, seq_len: int) -> int:
    if cfg.window is not None and seq_len > cfg.window:
        return cfg.window
    return seq_len


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _project(params, x, cfg: AttnCfg, positions):
    """x (B,T,D) -> q (B,T,H,Dh), k,v (B,T,KV,Dh); rope + optional qk-norm."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_section)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_section)
    return q, k, v


def _mask(q_pos, k_pos, cfg: AttnCfg):
    """(..., T, S) boolean validity from absolute positions."""
    m = k_pos[..., None, :] >= 0
    if cfg.causal and not cfg.cross:
        m &= k_pos[..., None, :] <= q_pos[..., :, None]
    if cfg.window is not None and not cfg.cross:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - cfg.window
    return m


def _sdpa_full(q, k, v, q_pos, k_pos, cfg: AttnCfg):
    """Materialized-scores attention. q (B,T,H,Dh), k/v (B,S,KV,Dh)."""
    b, t, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, t, kvh, rep, dh)
    scores = jnp.einsum("btgrk,bsgk->bgrts", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(dh)
    if cfg.softcap:
        scores = jnp.tanh(scores / cfg.softcap) * cfg.softcap
    mask = _mask(q_pos, k_pos, cfg)[:, None, None]        # (B,1,1,T,S)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgrts,bsgk->btgrk", w, v)
    return out.reshape(b, t, h, dh)


def _sdpa_chunked(q, k, v, q_pos, k_pos, cfg: AttnCfg, chunk: int):
    """Online-softmax over KV chunks (flash-style, pure jnp; memory O(T*chunk))."""
    b, t, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    n_chunks = s // chunk
    assert n_chunks * chunk == s, (s, chunk)
    qg = (q.reshape(b, t, kvh, rep, dh).astype(jnp.float32) / math.sqrt(dh))

    kc = k.reshape(b, n_chunks, chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kvh, dh).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        acc, m, l = carry
        kb, vb, pb = xs
        sc = jnp.einsum("btgrk,bsgk->bgrts", qg, kb.astype(jnp.float32))
        if cfg.softcap:
            sc = jnp.tanh(sc / cfg.softcap) * cfg.softcap
        msk = _mask(q_pos, pb, cfg)[:, None, None]
        sc = jnp.where(msk, sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bgrts,bsgk->bgrtk", p, vb.astype(jnp.float32))
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, kvh, rep, t, dh), jnp.float32)
    m0 = jnp.full((b, kvh, rep, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, t), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, t, h, dh).astype(q.dtype)


def _sdpa_banded(q, k, v, q_pos, k_pos, cfg: AttnCfg):
    """Sliding-window attention in banded form: queries in chunks of W only
    ever see keys in [chunk_start - W, chunk_end), so per-layer FLOPs/bytes
    drop from O(T^2) to O(T * 2W).  Exact for window <= W (masks still
    applied inside the band).  §Perf optimization for SWA prefill/train."""
    w = cfg.window
    b, t, h, dh = q.shape
    s, kvh = k.shape[1], k.shape[2]
    assert t == s and t % w == 0 and t // w >= 2
    rep = h // kvh
    nq = t // w
    qg = (q.reshape(b, nq, w, kvh, rep, dh).astype(jnp.float32)
          / math.sqrt(dh))
    qp = q_pos.reshape(b, nq, w)

    idx = (jnp.arange(nq, dtype=jnp.int32)[:, None] * w
           + jnp.arange(2 * w, dtype=jnp.int32)[None, :])       # (nq, 2W) into padded
    kpad = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    ppad = jnp.pad(k_pos, ((0, 0), (w, 0)), constant_values=-1)
    kb = kpad[:, idx]                                           # (B,nq,2W,KV,Dh)
    vb = vpad[:, idx]
    pb = ppad[:, idx]                                           # (B,nq,2W)

    sc = jnp.einsum("bnwgrk,bnsgk->bngrws", qg, kb.astype(jnp.float32))
    if cfg.softcap:
        sc = jnp.tanh(sc / cfg.softcap) * cfg.softcap
    m = pb[:, :, None, :] >= 0
    if cfg.causal:
        m &= pb[:, :, None, :] <= qp[..., None]
    m &= pb[:, :, None, :] > qp[..., None] - w
    sc = jnp.where(m[:, :, None, None], sc, NEG_INF)
    wts = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bngrws,bnsgk->bnwgrk", wts, vb.astype(jnp.float32))
    return out.reshape(b, t, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Layer-level entry points
# ---------------------------------------------------------------------------


def attention(params, x, cfg: AttnCfg, *, positions, mode: str,
              cache: Optional[dict], enc_kv=None, chunk: int = 1024,
              cache_len: Optional[int] = None):
    """Returns (out (B,T,D), new_cache).

    mode='train'   : no cache.
    mode='prefill' : builds cache (ring-truncated when window < S) with
                     capacity ``cache_len`` (>= T; empty slots pos=-1) so
                     subsequent decode steps append instead of overwriting.
    mode='decode'  : T == 1; reads + updates cache at ``positions`` (B,1).
    Cross-attention (cfg.cross): keys/values come from ``enc_kv`` (a dict with
    'k','v','pos'), cached wholesale at prefill.
    """
    dt = x.dtype
    b, t, d = x.shape

    if cfg.cross:
        return _cross_attention(params, x, cfg, cache=cache, enc_kv=enc_kv, mode=mode)

    q, k, v = _project(params, x, cfg, positions)
    # TP strategy: shard heads over 'model' when divisible; otherwise (e.g.
    # gemma3-4b's 8 heads on a 16-way model axis) fall back to sharding the
    # QUERY SEQUENCE over 'model' — context-parallel attention — instead of
    # replicating the whole attention block 16x (§Perf iteration 7).
    from repro.dist.sharding import ctx_axis_size
    msize = ctx_axis_size("model")
    heads_tp = msize is None or (cfg.n_heads % msize == 0)
    q_axes = (("batch", "seq", "act_heads", "head_dim") if heads_tp
              else ("batch", "act_seq_tp", "act_heads", "head_dim"))
    q = constrain(q, q_axes)
    k = constrain(k, ("batch", "seq", "act_kv_heads", "head_dim"))
    # masking / cache bookkeeping uses the temporal stream for M-RoPE
    mask_pos = positions[0] if positions.ndim == 3 else positions

    if mode == "decode":
        assert cache is not None and t == 1
        slot = mask_pos[:, 0] % cache["k"].shape[1]           # ring or full
        ck = _write_slot(cache["k"], k[:, 0], slot)
        cv = _write_slot(cache["v"], v[:, 0], slot)
        cp = _write_slot(cache["pos"], mask_pos[:, 0], slot)
        new_cache = {"k": ck, "v": cv, "pos": cp}
        out = _sdpa_full(q, ck, cv, mask_pos, cp, cfg)
    else:
        if mode == "prefill":
            clen = cache_len_for(cfg, max(cache_len or t, t))
            if clen < t:   # ring cache: keep the last `window` positions
                new_cache = {"k": _ring_tail(k, clen), "v": _ring_tail(v, clen),
                             "pos": _ring_tail(mask_pos[..., None], clen)[..., 0]}
            else:          # full cache padded to capacity; empty slots pos=-1
                pad = clen - t
                new_cache = {
                    "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                    "pos": jnp.pad(mask_pos, ((0, 0), (0, pad)),
                                   constant_values=-1),
                }
        else:
            new_cache = None
        use_banded = (cfg.window is not None and not cfg.cross
                      and t % cfg.window == 0 and t // cfg.window >= 2)
        use_chunked = t * k.shape[1] > 4096 * 4096 and k.shape[1] % chunk == 0
        if use_banded:
            out = _sdpa_banded(q, k, v, mask_pos, mask_pos, cfg)
        elif use_chunked:
            out = _sdpa_chunked(q, k, v, mask_pos, mask_pos, cfg, chunk)
        else:
            out = _sdpa_full(q, k, v, mask_pos, mask_pos, cfg)

    out = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(dt))
    out = constrain(out, ("batch", "seq", "act_embed"))
    return out, new_cache


def _write_slot(buf, val, slot):
    """buf (B,S,...) <- val (B,...) at per-batch slot (B,) int32."""
    bidx = jnp.arange(buf.shape[0])
    return buf.at[bidx, slot].set(val.astype(buf.dtype))


def _ring_tail(arr, clen):
    """Last clen positions of (B,T,...), laid out so slot = pos % clen."""
    b, t = arr.shape[:2]
    tail = arr[:, t - clen:]
    # roll so that absolute position p sits at slot p % clen
    shift = (t - clen) % clen
    return jnp.roll(tail, shift=shift, axis=1)


def _cross_attention(params, x, cfg: AttnCfg, *, cache, enc_kv, mode):
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
    if mode == "decode":
        k, v, kp = cache["k"], cache["v"], cache["pos"]
        new_cache = cache
    else:
        xe = enc_kv  # encoder hidden states (B, S_enc, D)
        k = jnp.einsum("bsd,dhk->bshk", xe, params["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", xe, params["wv"].astype(dt))
        kp = jnp.broadcast_to(jnp.arange(k.shape[1], dtype=jnp.int32)[None],
                              k.shape[:2])
        new_cache = {"k": k, "v": v, "pos": kp} if mode == "prefill" else None
    q_pos = jnp.zeros(q.shape[:2], jnp.int32)   # cross-attn: no causal mask
    out = _sdpa_full(q, k, v, q_pos, kp, cfg)
    out = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(dt))
    return out, new_cache


def cross_cache_specs(cfg: AttnCfg, batch: int, enc_len: int,
                      dtype=jnp.bfloat16) -> dict[str, TensorSpec]:
    kv, dh = cfg.n_kv, cfg.head_dim
    return {
        "k": tspec((batch, enc_len, kv, dh), ("batch", "kv_seq", "act_kv_heads", "head_dim"), dtype, init="zeros"),
        "v": tspec((batch, enc_len, kv, dh), ("batch", "kv_seq", "act_kv_heads", "head_dim"), dtype, init="zeros"),
        "pos": tspec((batch, enc_len), ("batch", "kv_seq"), jnp.int32, init="zeros"),
    }
