"""Selective state-space layers.

Mamba1 (falcon-mamba): per-channel diagonal SSM, chunked parallel scan —
``lax.scan`` over chunks carrying the (B, d_inner, N) state, an associative
scan *inside* each chunk (wrapped in ``jax.checkpoint`` so backward recomputes
chunk internals instead of saving (B,Tc,d,N) tensors).

Mamba2 (zamba2): SSD formulation — scalar decay per head; chunked
intra-(quadratic)/inter-(state) decomposition.

Both support: train (no cache), prefill (emit final state + conv tail),
decode (single-step recurrence).  Oracles: tests compare against a naive
per-timestep recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import Mamba1Cfg, Mamba2Cfg
from repro.dist.sharding import TensorSpec, constrain, tspec


# ---------------------------------------------------------------------------
# Depthwise causal conv (width w) over (B, T, C)
# ---------------------------------------------------------------------------


def causal_conv(x, w, b, tail=None):
    """x (B,T,C), w (W,C), b (C,). tail (B,W-1,C) prepended (decode/chunk)."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(width))
    return out + b.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------


def mamba1_specs(cfg: Mamba1Cfg, d_model: int) -> dict[str, TensorSpec]:
    di, n, r, w = cfg.d_inner, cfg.d_state, cfg.dt_rank or d_model // 16, cfg.conv_width
    return {
        "in_proj": tspec((d_model, 2 * di), ("embed", "ssm_inner")),
        "conv_w": tspec((w, di), (None, "conv_dim"), scale=0.2),
        "conv_b": tspec((di,), ("conv_dim",), init="zeros"),
        "x_proj": tspec((di, r + 2 * n), ("ssm_inner", None)),
        "dt_proj": tspec((r, di), ("dt_rank", "ssm_inner"), scale=r**-0.5),
        "dt_bias": tspec((di,), ("ssm_inner",), init="zeros"),
        "A_log": tspec((di, n), ("ssm_inner", "ssm_state"), init="zeros"),
        "D": tspec((di,), ("ssm_inner",), init="ones"),
        "out_proj": tspec((di, d_model), ("ssm_inner", "embed")),
    }


def mamba1_cache_specs(cfg: Mamba1Cfg, d_model: int, batch: int,
                       dtype=jnp.bfloat16) -> dict[str, TensorSpec]:
    di, n, w = cfg.d_inner, cfg.d_state, cfg.conv_width
    return {
        "conv": tspec((batch, w - 1, di), ("batch", None, "ssm_inner"), dtype, init="zeros"),
        "state": tspec((batch, di, n), ("batch", "ssm_inner", "ssm_state"), jnp.float32, init="zeros"),
    }


def _chunk_len(t: int, chunk: int) -> int:
    """Largest divisor of t that is <= chunk (odd prefill lengths fall back
    to shorter chunks rather than failing)."""
    tc = min(chunk, t)
    while t % tc:
        tc -= 1
    return tc


def _m1_scan_chunk(h0, a, b, serial: bool = False):
    """h0 (B,d,N); a,b (B,Tc,d,N). Returns h_all (B,Tc,d,N).

    serial=True: plain sequential scan over the chunk.  Hypothesis (§Perf
    iteration 8) was that log-depth associative scans touch HBM O(log Tc)
    times per element while a state-resident serial scan touches inputs
    once; MEASURED REFUTED on the compiled-HLO roofline (memory term 161s
    -> 319s): the per-step transposes + autodiff residuals of a 64-step
    while loop outweigh the level savings, and XLA fuses associative-scan
    levels better than assumed.  Kept selectable for documentation; the
    real fix for mamba1's memory term is a fused Pallas selective-scan
    kernel (kernels/ roadmap)."""
    if serial:
        def step(h, ab):
            at, bt = ab
            h = at * h + bt
            return h, h

        _, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
        return hs.swapaxes(0, 1)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    a_cum, b_cum = jax.lax.associative_scan(comb, (a, b), axis=1)
    return a_cum * h0[:, None] + b_cum


def mamba1(params, x, cfg: Mamba1Cfg, *, mode: str, cache):
    dt_ = x.dtype
    bsz, t, d_model = x.shape
    di, n = cfg.d_inner, cfg.d_state
    r = cfg.dt_rank or d_model // 16

    xz = jnp.einsum("btd,de->bte", x, params["in_proj"].astype(dt_))
    xz = constrain(xz, ("batch", "seq", "ssm_inner"))
    xa, z = jnp.split(xz, 2, axis=-1)

    conv_tail = cache["conv"] if cache is not None else None
    xa_raw = xa
    xa = jax.nn.silu(causal_conv(xa, params["conv_w"], params["conv_b"], conv_tail))

    dbc = jnp.einsum("bte,ef->btf", xa, params["x_proj"].astype(dt_))
    dt_r, bc, cc = jnp.split(dbc, [r, r + n], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("btr,re->bte", dt_r, params["dt_proj"].astype(dt_)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))                     # (B,T,di) f32
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                # (di,N)
    bc32, cc32, xa32 = bc.astype(jnp.float32), cc.astype(jnp.float32), xa.astype(jnp.float32)

    h_init = (cache["state"] if cache is not None
              else jnp.zeros((bsz, di, n), jnp.float32))

    if mode == "decode":
        assert t == 1
        a = jnp.exp(delta[:, 0, :, None] * A)                        # (B,di,N)
        b = (delta[:, 0] * xa32[:, 0])[..., None] * bc32[:, 0, None, :]
        h = a * h_init + b
        y = jnp.einsum("bdn,bn->bd", h, cc32[:, 0])[:, None]         # (B,1,di)
        new_cache = {"conv": jnp.concatenate(
            [conv_tail[:, 1:], xa_raw], axis=1).astype(conv_tail.dtype),
            "state": h}
    else:
        tc = _chunk_len(t, cfg.chunk)
        nc = t // tc

        def chunk_body(h0, xs):
            delta_c, xa_c, bc_c, cc_c = xs

            @jax.checkpoint
            def inner(h0, delta_c, xa_c, bc_c, cc_c):
                a = jnp.exp(delta_c[..., None] * A)                  # (B,Tc,di,N)
                b = (delta_c * xa_c)[..., None] * bc_c[:, :, None, :]
                h = _m1_scan_chunk(h0, a, b)
                y = jnp.einsum("btdn,btn->btd", h, cc_c)
                return h[:, -1], y

            return inner(h0, delta_c, xa_c, bc_c, cc_c)

        def to_chunks(arr):
            return arr.reshape(bsz, nc, tc, *arr.shape[2:]).swapaxes(0, 1)

        h_last, y = jax.lax.scan(
            chunk_body, h_init,
            (to_chunks(delta), to_chunks(xa32), to_chunks(bc32), to_chunks(cc32)))
        y = y.swapaxes(0, 1).reshape(bsz, t, di)
        if mode == "prefill":
            tail_len = cfg.conv_width - 1
            new_cache = {"conv": xa_raw[:, t - tail_len:].astype(dt_),
                         "state": h_last}
        else:
            new_cache = None

    y = y.astype(dt_) + params["D"].astype(dt_) * xa
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"].astype(dt_))
    return constrain(out, ("batch", "seq", "act_embed")), new_cache


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_specs(cfg: Mamba2Cfg, d_model: int) -> dict[str, TensorSpec]:
    di, n, p, w = cfg.d_inner, cfg.d_state, cfg.head_dim, cfg.conv_width
    h = di // p
    conv_dim = di + 2 * n
    return {
        "in_proj": tspec((d_model, 2 * di + 2 * n + h), ("embed", "ssm_inner")),
        "conv_w": tspec((w, conv_dim), (None, "conv_dim"), scale=0.2),
        "conv_b": tspec((conv_dim,), ("conv_dim",), init="zeros"),
        "A_log": tspec((h,), ("ssm_heads",), init="zeros"),
        "dt_bias": tspec((h,), ("ssm_heads",), init="zeros"),
        "D": tspec((h,), ("ssm_heads",), init="ones"),
        "norm": tspec((di,), ("ssm_inner",), init="ones"),
        "out_proj": tspec((di, d_model), ("ssm_inner", "embed")),
    }


def mamba2_cache_specs(cfg: Mamba2Cfg, d_model: int, batch: int,
                       dtype=jnp.bfloat16) -> dict[str, TensorSpec]:
    di, n, p, w = cfg.d_inner, cfg.d_state, cfg.head_dim, cfg.conv_width
    h = di // p
    return {
        "conv": tspec((batch, w - 1, di + 2 * n), ("batch", None, "conv_dim"), dtype, init="zeros"),
        "state": tspec((batch, h, p, n), ("batch", "ssm_heads", None, "ssm_state"), jnp.float32, init="zeros"),
    }


def mamba2(params, x, cfg: Mamba2Cfg, *, mode: str, cache):
    from repro.models.common import rmsnorm

    dt_ = x.dtype
    bsz, t, d_model = x.shape
    di, n, p = cfg.d_inner, cfg.d_state, cfg.head_dim
    nh = di // p

    zxd = jnp.einsum("btd,de->bte", x, params["in_proj"].astype(dt_))
    zxd = constrain(zxd, ("batch", "seq", "ssm_inner"))
    z, xbc, dt_head = jnp.split(zxd, [di, 2 * di + 2 * n], axis=-1)

    conv_tail = cache["conv"] if cache is not None else None
    xbc_raw = xbc
    xbc = jax.nn.silu(causal_conv(xbc, params["conv_w"], params["conv_b"], conv_tail))
    xs, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    xs = xs.reshape(bsz, t, nh, p).astype(jnp.float32)
    b32, c32 = b_in.astype(jnp.float32), c_in.astype(jnp.float32)

    delta = jax.nn.softplus(dt_head.astype(jnp.float32)
                            + params["dt_bias"].astype(jnp.float32))  # (B,T,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                 # (H,)
    da = delta * A                                                    # (B,T,H)

    s_init = (cache["state"] if cache is not None
              else jnp.zeros((bsz, nh, p, n), jnp.float32))

    if mode == "decode":
        assert t == 1
        decay = jnp.exp(da[:, 0])                                     # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", delta[:, 0], xs[:, 0], b32[:, 0])
        s = decay[..., None, None] * s_init + upd
        y = jnp.einsum("bn,bhpn->bhp", c32[:, 0], s)[:, None]         # (B,1,H,P)
        y = y + params["D"].astype(jnp.float32)[:, None] * xs[:, :1]
        new_cache = {"conv": jnp.concatenate(
            [conv_tail[:, 1:], xbc_raw], axis=1).astype(conv_tail.dtype),
            "state": s}
    else:
        tc = _chunk_len(t, cfg.chunk)
        nc = t // tc

        def chunk_body(s0, xs_):
            da_c, x_c, b_c, c_c, delta_c = xs_

            @jax.checkpoint
            def inner(s0, da_c, x_c, b_c, c_c, delta_c):
                cum = jnp.cumsum(da_c, axis=1)                        # (B,Tc,H)
                li = cum[:, :, None, :] - cum[:, None, :, :]          # (B,i,j,H)
                tri = jnp.tril(jnp.ones((tc, tc), bool))
                L = jnp.where(tri[None, :, :, None], jnp.exp(li), 0.0)
                sc = jnp.einsum("bin,bjn->bij", c_c, b_c)
                xw = x_c * delta_c[..., None]                          # (B,Tc,H,P)
                y = jnp.einsum("bij,bijh,bjhp->bihp", sc, L, xw)
                y = y + jnp.einsum("bih,bin,bhpn->bihp", jnp.exp(cum), c_c, s0)
                dec_out = jnp.exp(cum[:, -1:, :] - cum)               # (B,Tc,H)
                s_new = (jnp.exp(cum[:, -1])[..., None, None] * s0
                         + jnp.einsum("bjh,bjn,bjhp->bhpn", dec_out * delta_c, b_c, x_c))
                return s_new, y

            return inner(s0, da_c, x_c, b_c, c_c, delta_c)

        def to_chunks(arr):
            return arr.reshape(bsz, nc, tc, *arr.shape[2:]).swapaxes(0, 1)

        s_last, y = jax.lax.scan(
            chunk_body, s_init,
            (to_chunks(da), to_chunks(xs), to_chunks(b32), to_chunks(c32),
             to_chunks(delta)))
        y = y.swapaxes(0, 1).reshape(bsz, t, nh, p)
        y = y + params["D"].astype(jnp.float32)[:, None] * xs.reshape(bsz, t, nh, p)
        if mode == "prefill":
            tail_len = cfg.conv_width - 1
            new_cache = {"conv": xbc_raw[:, t - tail_len:].astype(dt_),
                         "state": s_last}
        else:
            new_cache = None

    y = y.reshape(bsz, t, di).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"])
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"].astype(dt_))
    return constrain(out, ("batch", "seq", "act_embed")), new_cache
