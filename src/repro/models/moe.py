"""Top-k routed Mixture-of-Experts with GROUPED capacity-bounded dispatch.

Tokens are split into ``n_groups`` contiguous groups aligned with the batch
sharding (GShard semantics): router positions/capacity are computed WITHIN a
group, so the dispatch scatter is local to the data shard that owns the
group, and the (groups -> experts) reshard of the dispatch buffer lowers to
one all-to-all per layer under GSPMD instead of the pathological
all-gather+scatter a global-index dispatch produces (§Perf iteration 2:
~25 TB/device of collectives on mixtral-8x22b -> ~40 GB).

Tokens beyond per-(group, expert) capacity are dropped (standard
GShard/Switch semantics, capacity_factor-controlled).  Arctic-style parallel
*dense residual* MLP supported via cfg.dense_residual_ff.

Invariants (property-tested in tests/test_models.py):
  * each token routes to exactly top_k distinct experts;
  * combine weights of kept assignments match softmaxed router gates;
  * with a generous capacity_factor nothing is dropped and the layer equals
    the per-token dense mixture oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MlpCfg, MoECfg
from repro.dist.sharding import TensorSpec, constrain, tspec
from repro.models.mlp import mlp, mlp_specs

DEFAULT_GROUPS = 32


def moe_specs(cfg: MoECfg, d_model: int) -> dict[str, TensorSpec]:
    e, f = cfg.n_experts, cfg.d_ff
    s = {
        "router": tspec((d_model, e), ("embed", "expert"), scale=d_model**-0.5),
        "w_gate": tspec((e, d_model, f), ("expert", "embed", "expert_mlp")),
        "w_up": tspec((e, d_model, f), ("expert", "embed", "expert_mlp")),
        "w_down": tspec((e, f, d_model), ("expert", "expert_mlp", "embed")),
    }
    if cfg.dense_residual_ff:
        for k, v in mlp_specs(MlpCfg(cfg.dense_residual_ff), d_model).items():
            s["res_" + k] = v
    return s


def group_count(n_tokens: int, want: int | None = None) -> int:
    """Groups scale with token count: decode-sized batches (<=256 tokens)
    use ONE group — per-(group,expert) capacity floors otherwise inflate the
    dispatch buffer ~100x for one-token steps (§Perf iteration 9: arctic
    decode_32k collective 2.4s -> back under the baseline)."""
    if want is None:
        want = min(DEFAULT_GROUPS, max(1, n_tokens // 256))
    g = min(want, n_tokens)
    while n_tokens % g:
        g -= 1
    return g


def capacity(cfg: MoECfg, group_tokens: int) -> int:
    c = int(math.ceil(cfg.capacity_factor * group_tokens * cfg.top_k
                      / cfg.n_experts))
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4 (TPU lanes)


def moe(params, x, cfg: MoECfg, *, return_aux: bool = False):
    """x (B,T,D) -> (B,T,D). Router in fp32; experts in compute dtype."""
    dt = x.dtype
    b, t, d = x.shape
    n = b * t
    e, k = cfg.n_experts, cfg.top_k
    g = group_count(n)
    ng = n // g                                  # tokens per group
    cap = capacity(cfg, ng)

    xg = x.reshape(g, ng, d)
    xg = constrain(xg, ("moe_group", None, "act_embed"))
    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    top_logits, top_idx = jax.lax.top_k(logits, k)            # (G,ng,k)
    gates = jax.nn.softmax(top_logits, axis=-1)               # mixtral-style

    # position of each (token, slot) within its (group, expert)
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)      # (G,ng,k,E)
    flat = onehot.reshape(g, ng * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                     # exclusive
    pos = (pos * flat).sum(-1).reshape(g, ng, k)              # (G,ng,k)
    keep = pos < cap
    dest = top_idx * cap + pos                                # (G,ng,k)

    # local scatter into per-group dispatch buffers (G, E*cap, D).  vmap over
    # the group axis makes it a *batched* scatter (operand_batching_dims), so
    # GSPMD partitions it on the group shard instead of replicating
    # (explicit 2D index arrays defeat the partitioner — §Perf iteration 6).
    src = (xg[:, :, None, :] * keep[..., None].astype(dt)).reshape(g, ng * k, d)

    def _scatter_one(idx, upd):
        return jnp.zeros((e * cap, d), dt).at[idx].add(upd, mode="drop")

    disp = jax.vmap(_scatter_one)(dest.reshape(g, ng * k), src)
    disp = disp.reshape(g, e, cap, d)
    # groups->experts reshard: one all-to-all per layer under GSPMD
    disp = constrain(disp, ("moe_group", "expert", "moe_cap", "act_embed"))

    gate = jnp.einsum("gecd,edf->gecf", disp, params["w_gate"].astype(dt))
    up = jnp.einsum("gecd,edf->gecf", disp, params["w_up"].astype(dt))
    h = jax.nn.silu(gate) * up
    h = constrain(h, ("moe_group", "expert", "moe_cap", "expert_mlp"))
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(dt))
    out = out.reshape(g, e * cap, d)
    # experts -> groups reshard (the inverse all-to-all) BEFORE the combine
    # gather, so the gather itself is local to the group's data shard —
    # without this, GSPMD replicates the whole dispatch buffer per device
    # (§Perf iteration 5: arctic collective 612s -> see EXPERIMENTS.md).
    out = constrain(out, ("moe_group", None, "act_embed"))

    # combine: batched gather of each kept assignment back to its token
    gathered = jax.vmap(lambda o, i: o[i])(out, dest.reshape(g, ng * k))
    gathered = gathered.reshape(g, ng, k, d)
    w = (gates.astype(dt) * keep.astype(dt))[..., None]
    y = (gathered * w).sum(axis=2).reshape(b, t, d)
    y = constrain(y, ("batch", "seq", "act_embed"))

    if cfg.dense_residual_ff:
        res = {kk[4:]: v for kk, v in params.items() if kk.startswith("res_")}
        y = y + mlp(res, x, MlpCfg(cfg.dense_residual_ff))

    if return_aux:
        aux = {
            "kept_fraction": keep.mean(),
            "router_entropy": -(jax.nn.softmax(logits, -1)
                                * jax.nn.log_softmax(logits, -1)).sum(-1).mean(),
            "load_balance_loss": load_balance_loss(logits, top_idx),
            "top_idx": top_idx.reshape(n, k),
            "pos": pos.reshape(n, k),
            "gates": gates.reshape(n, k),
        }
        return y, aux
    return y


def load_balance_loss(router_logits, top_idx):
    """Switch-style auxiliary loss: E * sum_e f_e * p_e, where f_e is the
    fraction of tokens routed to expert e and p_e the mean router prob.
    Minimized (=1) at perfectly uniform routing; add with a small coeff to
    the LM loss to keep experts from collapsing."""
    e = router_logits.shape[-1]
    probs = jax.nn.softmax(router_logits, axis=-1)          # (G,ng,E)
    frac = jax.nn.one_hot(top_idx, e).mean(axis=(0, 1, 2))  # (E,) routed frac
    pmean = probs.mean(axis=(0, 1))                         # (E,)
    return e * jnp.sum(frac * pmean)
