"""Shared model components: RMSNorm, RoPE (incl. M-RoPE), embedding specs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import TensorSpec, tspec


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> TensorSpec:
    return tspec((d,), ("act_embed",), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def _rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., dim//2)."""
    half = dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions[..., None].astype(jnp.float32) * freqs


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               mrope_section: tuple[int, ...] | None = None) -> jax.Array:
    """Rotate pairs (x[..., :half], x[..., half:]).

    x: (B, T, H, D). positions: (B, T) — or (3, B, T) for M-RoPE, where the
    head-dim half is split into ``mrope_section`` chunks rotated by the t/h/w
    position streams respectively (Qwen2-VL).
    """
    d = x.shape[-1]
    half = d // 2
    if mrope_section is None:
        ang = _rope_angles(positions, d, theta)          # (B, T, half)
    else:
        assert positions.ndim == 3 and positions.shape[0] == len(mrope_section)
        parts = [
            _mrope_part(positions[i], sec, d, theta, sum(mrope_section[:i]))
            for i, sec in enumerate(mrope_section)
        ]
        ang = jnp.concatenate(parts, axis=-1)            # (B, T, half)
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dt)


def _mrope_part(pos: jax.Array, sec: int, d: int, theta: float, offset: int) -> jax.Array:
    """Frequencies for an M-RoPE section use the *global* frequency ladder
    (indices offset..offset+sec of the d//2 ladder), per Qwen2-VL."""
    half = d // 2
    idx = jnp.arange(offset, offset + sec, dtype=jnp.float32)
    freqs = theta ** (-idx / half)
    return pos[..., None].astype(jnp.float32) * freqs


def default_positions(batch: int, seq: int, mrope: bool = False) -> jax.Array:
    p = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    if mrope:
        return jnp.broadcast_to(p[None], (3, batch, seq))
    return p


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embed_spec(vocab: int, d: int) -> TensorSpec:
    return tspec((vocab, d), ("vocab", "embed"), init="embed")


def unembed_spec(d: int, vocab: int) -> TensorSpec:
    return tspec((d, vocab), ("embed", "vocab"))


def embed_tokens(table: jax.Array, tokens: jax.Array, scale: float | None,
                 dtype=jnp.bfloat16) -> jax.Array:
    x = table.astype(dtype)[tokens]
    if scale is not None:
        x = x * jnp.asarray(scale, dtype)
    return x
