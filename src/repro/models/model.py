"""Top-level models: decoder-only LM and encoder-decoder (whisper).

Pure-function API over TensorSpec param trees:
  model_specs(cfg)                      -> param spec pytree (no allocation)
  model_cache_specs(cfg, batch, S, ...) -> KV/SSM cache spec pytree
  forward(params, cfg, inputs, ...)     -> logits (+ cache for prefill/decode)
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.dist.sharding import constrain, tspec
from repro.models.common import (default_positions, embed_spec, embed_tokens,
                                 rmsnorm, rmsnorm_spec, unembed_spec)
from repro.models.stack import apply_stack, stack_cache_specs, stack_specs


def model_specs(cfg: ModelCfg) -> dict[str, Any]:
    d = cfg.d_model
    s: dict[str, Any] = {
        "embed": embed_spec(cfg.vocab, d),
        "stack": stack_specs(cfg.stack, d),
        "final_norm": rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = unembed_spec(d, cfg.vocab)
    if cfg.encoder is not None:
        s["encoder"] = stack_specs(cfg.encoder, d)
        s["enc_norm"] = rmsnorm_spec(d)
    return s


def model_cache_specs(cfg: ModelCfg, batch: int, seq_len: int,
                      enc_len: int | None = None,
                      dtype=jnp.bfloat16) -> dict[str, Any]:
    return stack_cache_specs(cfg.stack, cfg.d_model, batch, seq_len,
                             enc_len, dtype)


def _mrope(cfg: ModelCfg) -> bool:
    for lc in cfg.stack.pattern + cfg.stack.tail:
        if lc.attn is not None and lc.attn.mrope_section:
            return True
    return False


def encode(params, cfg: ModelCfg, enc_inputs, *, remat="none"):
    """Encoder forward (whisper): enc_inputs (B, S_enc, D) stub embeddings."""
    x = enc_inputs.astype(cfg.compute_dtype)
    b, s, _ = x.shape
    aux = {"positions": default_positions(b, s), "enc": None}
    x, _ = apply_stack(params["encoder"], x, cfg.encoder, mode="train",
                       cache=None, aux=aux, eps=cfg.norm_eps, remat=remat)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def lm_head(params, cfg: ModelCfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, cfg: ModelCfg, inputs, *, mode: str = "train",
            cache=None, positions=None, enc_inputs=None, remat: str = "none",
            logits_f32: bool = True, return_hidden: bool = False,
            cache_len: Optional[int] = None):
    """inputs: tokens (B,T) int32, or embeddings (B,T,D) when
    cfg.embed_inputs is False (audio/vlm stub frontends) in train/prefill.

    Returns logits (B,T,V) for train; (logits, cache) for prefill/decode.
    """
    dt = cfg.compute_dtype
    if inputs.ndim == 2:  # token ids
        scale = math.sqrt(cfg.d_model) if cfg.embed_scale else None
        x = embed_tokens(params["embed"], inputs, scale, dt)
    else:
        x = inputs.astype(dt)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    x = constrain(x, ("batch", "seq", "act_embed"))
    b, t = x.shape[:2]

    if positions is None:
        positions = default_positions(b, t, _mrope(cfg))

    enc = None
    if cfg.encoder is not None and mode != "decode":
        assert enc_inputs is not None, "enc-dec model needs encoder inputs"
        enc = encode(params, cfg, enc_inputs, remat=remat)

    aux = {"positions": positions, "enc": enc, "cache_len": cache_len}
    x, new_cache = apply_stack(params["stack"], x, cfg.stack, mode=mode,
                               cache=cache, aux=aux, eps=cfg.norm_eps,
                               remat=remat)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x if mode == "train" else (x, new_cache)

    head = lm_head(params, cfg)
    logits = jnp.einsum("btd,dv->btv", x, head.astype(dt))
    logits = constrain(logits, ("batch", "seq", "act_vocab"))
    if logits_f32:
        logits = logits.astype(jnp.float32)
    if mode == "train":
        return logits
    return logits, new_cache


def decode_positions(pos, batch: int, mrope: bool = False):
    """pos: scalar int32 -> (B,1) positions (or (3,B,1) for mrope)."""
    p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(1, 1), (batch, 1))
    if mrope:
        return jnp.broadcast_to(p[None], (3, batch, 1))
    return p
