"""Dense feed-forward: SwiGLU (gated) or GeLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MlpCfg
from repro.dist.sharding import TensorSpec, constrain, tspec


def mlp_specs(cfg: MlpCfg, d_model: int) -> dict[str, TensorSpec]:
    if cfg.gated:
        return {
            "w_gate": tspec((d_model, cfg.d_ff), ("embed", "mlp")),
            "w_up": tspec((d_model, cfg.d_ff), ("embed", "mlp")),
            "w_down": tspec((cfg.d_ff, d_model), ("mlp", "embed")),
        }
    return {
        "w_up": tspec((d_model, cfg.d_ff), ("embed", "mlp")),
        "w_down": tspec((cfg.d_ff, d_model), ("mlp", "embed")),
    }


def mlp(params, x, cfg: MlpCfg):
    dt = x.dtype
    up = jnp.einsum("btd,df->btf", x, params["w_up"].astype(dt))
    if cfg.gated:
        gate = jnp.einsum("btd,df->btf", x, params["w_gate"].astype(dt))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    h = constrain(h, ("batch", "seq", "act_mlp"))
    out = jnp.einsum("btf,fd->btd", h, params["w_down"].astype(dt))
    return constrain(out, ("batch", "seq", "act_embed"))
