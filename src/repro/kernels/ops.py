"""jit'd wrappers around the Pallas kernels (+ oracle fallbacks).

On this CPU container kernels run in interpret mode (correctness); on TPU
set interpret=False.  ``use_kernels(False)`` routes everything to the
pure-jnp oracles in ref.py.  The kernel-backed record reader
(core.query.read_hail_kernels) calls through these wrappers and is asserted
equivalent to the jnp reader by the system test suite, so kernel/oracle
agreement is exercised end-to-end, not only by per-kernel allclose tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.block_sort import bitonic_sort
from repro.kernels.flash_attention import flash_attention
from repro.kernels.index_search import index_search as _index_search
from repro.kernels.pax_scan import pax_scan as _pax_scan

_USE_KERNELS = True
_INTERPRET = True   # CPU container: interpret mode; False on real TPUs


def use_kernels(on: bool):
    global _USE_KERNELS
    _USE_KERNELS = on


def sort_block(keys: jax.Array, cols: dict[str, jax.Array]):
    """Sort one block by key, permuting all PAX columns.
    keys (blocks, n) -> (sorted_keys, permuted cols)."""
    if _USE_KERNELS and keys.shape[-1] & (keys.shape[-1] - 1) == 0:
        sorted_keys, perm = bitonic_sort(keys, interpret=_INTERPRET)
    else:
        sorted_keys, perm = jax.vmap(ref.sort_by_key)(keys)
    out = {c: jnp.take_along_axis(v, perm, axis=1) for c, v in cols.items()}
    return sorted_keys, out, perm


def index_search(mins: jax.Array, lo: int, hi: int) -> jax.Array:
    if _USE_KERNELS:
        return _index_search(mins, lo, hi, interpret=_INTERPRET)
    return ref.index_search(mins, lo, hi)


def pax_scan(key_col: jax.Array, proj: jax.Array, lo: int, hi: int):
    if _USE_KERNELS:
        return _pax_scan(key_col, proj, lo, hi, interpret=_INTERPRET)
    return ref.pax_scan(key_col, proj, lo, hi)


def attention(q, k, v, *, causal=True, window=None):
    if _USE_KERNELS:
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_INTERPRET)
    return ref.attention(q, k, v, causal=causal, window=window)
