"""jit'd wrappers around the Pallas kernels (+ oracle fallbacks).

On this CPU container kernels run in interpret mode (correctness); on a
real TPU export ``REPRO_PALLAS_INTERPRET=0`` (or call ``set_interpret``)
to lower through Mosaic — no code edit needed.  ``use_kernels(False)``
routes everything to the pure-jnp oracles in ref.py.  The kernel-backed record reader
(core.query.read_hail_kernels) calls through these wrappers and is asserted
equivalent to the jnp reader by the system test suite, so kernel/oracle
agreement is exercised end-to-end, not only by per-kernel allclose tests.

Dispatch/recompile accounting: every wrapper that backs the record reader
bumps ``DISPATCH_COUNTS`` per call and ``TRACE_COUNTS`` per retrace (a
Python side effect inside the traced body runs only when jit actually
recompiles).  ``reader_stats()`` / ``reset_stats()`` expose them; the
no-recompile acceptance tests and bench_kernels' BENCH_kernels.json
regression-guard the counts.  (lo, hi) are traced arguments everywhere —
new query ranges reuse the compiled readers.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checksum as _ck
from repro.kernels import ref
from repro.kernels.block_sort import bitonic_sort
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hail_reader import hail_read as _hail_read
from repro.kernels.hail_reader import hail_read_batch as _hail_read_batch
from repro.kernels.index_search import index_search as _index_search
from repro.kernels.pax_scan import pax_scan as _pax_scan
from repro.obs import trace as _obs_trace

_USE_KERNELS = True


def _env_interpret() -> bool:
    """Pallas interpret mode from the environment: the real-TPU flip is
    ``REPRO_PALLAS_INTERPRET=0`` (or false/off) — no code edit needed.
    Default is interpret (this CPU container has no Mosaic backend)."""
    v = os.environ.get("REPRO_PALLAS_INTERPRET", "1")
    return v.strip().lower() not in ("0", "false", "off", "no")


_INTERPRET = _env_interpret()

DISPATCH_COUNTS: collections.Counter = collections.Counter()
TRACE_COUNTS: collections.Counter = collections.Counter()


def use_kernels(on: bool):
    global _USE_KERNELS
    _USE_KERNELS = on


def interpret_mode() -> bool:
    return _INTERPRET


def set_interpret(on: bool):
    """Flip interpret/compiled Pallas at RUNTIME (overrides the env default).

    The jitted reader wrappers bake the flag in at trace time, so flipping
    clears their jit caches — the next call retraces under the new mode.
    """
    global _INTERPRET
    on = bool(on)
    if on == _INTERPRET:
        return
    _INTERPRET = on
    for fn in (_index_search_jit, _pax_scan_jit, _hail_read_jit,
               _hail_read_ref_jit, _hail_read_batch_jit,
               _hail_read_batch_ref_jit):
        fn.clear_cache()


def reset_stats():
    DISPATCH_COUNTS.clear()
    TRACE_COUNTS.clear()


def reader_stats() -> dict:
    return {"dispatches": dict(DISPATCH_COUNTS),
            "traces": dict(TRACE_COUNTS)}


class StatsScope:
    """Handle yielded by ``stats_scope`` — holds the scope's counters so
    assertions can also run after the ``with`` block exits."""

    def __init__(self, dispatches: collections.Counter,
                 traces: collections.Counter):
        self.dispatches = dispatches
        self.traces = traces


@contextlib.contextmanager
def stats_scope(merge: bool = True):
    """Isolated dispatch/trace counters for one test or measurement block.

    Swaps FRESH counters into the module globals on entry and restores the
    previous ones on exit (merging the scope's counts back in unless
    ``merge=False``), so dispatch-count assertions see only the calls made
    inside the scope — independent of test order — instead of relying on
    module-global ``reset_stats`` mutation racing other tests.

        with ops.stats_scope() as s:
            q.read_hail_kernels(store, query, qp)
        assert s.dispatches["hail_read"] == 1

    Note: trace counts are still a property of jit's process-wide cache — a
    scope observes a retrace only if compilation actually happens inside it.
    """
    global DISPATCH_COUNTS, TRACE_COUNTS
    prev_d, prev_t = DISPATCH_COUNTS, TRACE_COUNTS
    DISPATCH_COUNTS = collections.Counter()
    TRACE_COUNTS = collections.Counter()
    scope = StatsScope(DISPATCH_COUNTS, TRACE_COUNTS)
    try:
        yield scope
    finally:
        if merge:
            prev_d.update(scope.dispatches)
            prev_t.update(scope.traces)
        DISPATCH_COUNTS, TRACE_COUNTS = prev_d, prev_t


def sort_block(keys: jax.Array, cols: dict[str, jax.Array]):
    """Sort one block by key, permuting all PAX columns.
    keys (blocks, n) -> (sorted_keys, permuted cols)."""
    if _USE_KERNELS and keys.shape[-1] & (keys.shape[-1] - 1) == 0:
        sorted_keys, perm = bitonic_sort(keys, interpret=_INTERPRET)
    else:
        sorted_keys, perm = jax.vmap(ref.sort_by_key)(keys)
    out = {c: jnp.take_along_axis(v, perm, axis=1) for c, v in cols.items()}
    return sorted_keys, out, perm


# -- jitted entry points: lo/hi TRACED, shapes/statics are the only cache keys


@jax.jit
def _index_search_jit(mins, lo, hi):
    TRACE_COUNTS["index_search"] += 1
    return _index_search(mins, lo, hi, interpret=_INTERPRET)


@jax.jit
def _pax_scan_jit(key_col, proj, lo, hi):
    TRACE_COUNTS["pax_scan"] += 1
    return _pax_scan(key_col, proj, lo, hi, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("partition_size",))
def _hail_read_jit(mins, keys, proj, bad, use_index, lo, hi,
                   *, partition_size):
    TRACE_COUNTS["hail_read"] += 1
    return _hail_read(mins, keys, proj, bad, use_index, lo, hi,
                      partition_size=partition_size, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("partition_size",))
def _hail_read_ref_jit(mins, keys, proj, bad, use_index, lo, hi,
                       *, partition_size):
    TRACE_COUNTS["hail_read_ref"] += 1
    return ref.hail_read(mins, keys, proj, bad, use_index, lo, hi,
                         partition_size=partition_size)


@functools.partial(jax.jit, static_argnames=("partition_size",))
def _hail_read_batch_jit(mins, keys, proj, bad, use_index, lohi,
                         *, partition_size):
    TRACE_COUNTS["hail_read_batch"] += 1
    return _hail_read_batch(mins, keys, proj, bad, use_index, lohi,
                            partition_size=partition_size,
                            interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("partition_size",))
def _hail_read_batch_ref_jit(mins, keys, proj, bad, use_index, lohi,
                             *, partition_size):
    TRACE_COUNTS["hail_read_batch_ref"] += 1
    return ref.hail_read_batch(mins, keys, proj, bad, use_index, lohi,
                               partition_size=partition_size)


@jax.jit
def _verify_blocks_jit(data, sums):
    TRACE_COUNTS["verify_blocks"] += 1
    return _ck.verify_blocks(data, sums)


@functools.partial(jax.jit, static_argnames=("partition_size",))
def _verify_root_jit(mins, keys, *, partition_size):
    TRACE_COUNTS["verify_root"] += 1
    return _ck.verify_root(mins, keys, partition_size)


def verify_blocks(data, sums) -> jax.Array:
    """Batched chunk-checksum verify: data (C, B, rows) int32 columns
    stacked, sums (C, B, chunks) uint32 -> bool (C, B).  ONE dispatch per
    call; the read path calls it once per BlockCache fill, so verification
    cost amortizes across cache hits.  ``verify_block_cols`` counts the
    (col, block) pairs proven, for the clean-path overhead guard."""
    DISPATCH_COUNTS["verify_blocks"] += 1
    DISPATCH_COUNTS["verify_block_cols"] += int(data.shape[0] * data.shape[1])
    _obs_trace.instant("verify_blocks", track="kernels", cat="dispatch",
                       args={"cols": int(data.shape[0]),
                             "blocks": int(data.shape[1])})
    return _verify_blocks_jit(data, sums)


def verify_root(mins, keys, *, partition_size: int) -> jax.Array:
    """Root-directory consistency check (mins vs sorted key column)."""
    DISPATCH_COUNTS["verify_root"] += 1
    return _verify_root_jit(mins, keys, partition_size=partition_size)


def index_search(mins: jax.Array, lo, hi) -> jax.Array:
    DISPATCH_COUNTS["index_search"] += 1
    if _USE_KERNELS:
        return _index_search_jit(mins, lo, hi)
    return ref.index_search(mins, lo, hi)


def pax_scan(key_col: jax.Array, proj: jax.Array, lo, hi):
    DISPATCH_COUNTS["pax_scan"] += 1
    if _USE_KERNELS:
        return _pax_scan_jit(key_col, proj, lo, hi)
    return ref.pax_scan(key_col, proj, lo, hi)


def hail_read(mins, keys, proj, bad, use_index, lo, hi, *,
              partition_size: int):
    """Fused split reader: ONE dispatch per call (== per split).

    ``use_index`` should be a HOST (numpy) array: the per-block scan-mode
    counters read it before it ships to the device, so the non-blocking
    dispatch path stays free of device->host syncs.  (Per-filter-column
    attribution — ``index_scan_blocks[col]`` etc. — is the record readers'
    job via ``governor.attribute_read``, which writes the same
    ``DISPATCH_COUNTS``; this wrapper only knows shapes, not columns.)"""
    DISPATCH_COUNTS["hail_read"] += 1
    # adaptive-convergence tests assert full_scan_blocks hits 0
    u = np.asarray(use_index)        # no-op for the host-array callers
    n_idx = int(u.astype(bool).sum())
    DISPATCH_COUNTS["index_scan_blocks"] += n_idx
    DISPATCH_COUNTS["full_scan_blocks"] += u.shape[0] - n_idx
    _obs_trace.instant("hail_read", track="kernels", cat="dispatch",
                       args={"index_blocks": n_idx,
                             "full_blocks": int(u.shape[0]) - n_idx})
    fn = _hail_read_jit if _USE_KERNELS else _hail_read_ref_jit
    return fn(mins, keys, proj, bad, jnp.asarray(u, jnp.int32),
              jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32),
              partition_size=partition_size)


def hail_read_batch(mins, keys, proj, bad, use_index, lohi, *,
                    partition_size: int):
    """Fused shared-scan reader: ONE dispatch per (split, query-batch).

    ``lohi`` is the batch's (Q, 2) runtime lo/hi array; Q is a SHAPE, so a
    server batching at a fixed ``max_batch`` compiles one variant per
    distinct batch size (counted in ``traces``) and reuses it for every
    later batch of that size.  The scan-mode counters charge each of the Q
    queries with the blocks it logically scanned — serially-equivalent
    accounting, so adaptive/governor invariant tests see the same totals
    whether traffic was batched or not.  Per-column attribution stays the
    record readers' job (``governor.attribute_read``, once per query)."""
    DISPATCH_COUNTS["hail_read"] += 1
    DISPATCH_COUNTS["hail_read_batch"] += 1
    lohi = np.asarray(lohi, np.int32).reshape(-1, 2)
    n_q = lohi.shape[0]
    u = np.asarray(use_index)        # host array: counters cost no sync
    n_idx = int(u.astype(bool).sum())
    DISPATCH_COUNTS["index_scan_blocks"] += n_q * n_idx
    DISPATCH_COUNTS["full_scan_blocks"] += n_q * (u.shape[0] - n_idx)
    _obs_trace.instant("hail_read_batch", track="kernels", cat="dispatch",
                       args={"queries": n_q, "index_blocks": n_idx,
                             "full_blocks": int(u.shape[0]) - n_idx})
    fn = _hail_read_batch_jit if _USE_KERNELS else _hail_read_batch_ref_jit
    return fn(mins, keys, proj, bad, jnp.asarray(u, jnp.int32),
              jnp.asarray(lohi), partition_size=partition_size)


@functools.lru_cache(maxsize=None)
def _sharded_batch_reader(mesh, axes: tuple, partition_size: int,
                          use_kernels: bool, interpret: bool):
    """shard_map'd fused batch reader, compiled once per (mesh, axes,
    partition_size, backend) — the kernel/interpret flags are CACHE KEYS
    here (not baked globals), so ``set_interpret``/``use_kernels`` flips
    pick a fresh entry without any cache clearing."""
    try:
        from jax import shard_map                      # jax >= 0.6
    except ImportError:
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    spec = P(axes if len(axes) > 1 else axes[0])

    def local(mins, keys, proj, bad, use_index, lohi):
        TRACE_COUNTS["hail_read_sharded"] += 1
        if use_kernels:
            return _hail_read_batch(mins, keys, proj, bad, use_index, lohi,
                                    partition_size=partition_size,
                                    interpret=interpret)
        return ref.hail_read_batch(mins, keys, proj, bad, use_index, lohi,
                                   partition_size=partition_size)

    # block dim sharded over the scan axes; the (Q, 2) ranges replicated.
    # check_rep=False: outputs are per-shard block tiles, no replication
    # invariant for the checker to prove through the pallas call.
    fn = shard_map(local, mesh=mesh,
                   in_specs=(spec, spec, spec, spec, spec, P()),
                   out_specs=(spec, spec, spec), check_rep=False)
    return jax.jit(fn)


def hail_read_batch_sharded(mins, keys, proj, bad, use_index, lohi, *,
                            partition_size: int, mesh, axes,
                            n_splits: int = 1):
    """Sharded fused reader: ONE dispatch per WAVE of up to n_dev splits.

    The leading (block) dim must equal ``n_dev * blocks_per_device`` — the
    wave assembler in core.query pads ragged splits with dead blocks and
    stacks them — and is shard_map'd over ``axes`` of ``mesh``, so every
    device scans its own split's block tile against the same replicated
    (Q, 2) ranges.  Per-device fused dispatches therefore equal the wave
    count = ceil(splits / n_dev).  Scan-mode counters are the CALLER's job
    (only it knows which blocks are padding); this wrapper counts waves
    and the splits they carry."""
    axes = tuple(axes)
    DISPATCH_COUNTS["hail_read_sharded_waves"] += 1
    DISPATCH_COUNTS["hail_read_sharded_splits"] += int(n_splits)
    _obs_trace.instant("hail_read_sharded", track="kernels", cat="dispatch",
                       args={"splits": int(n_splits),
                             "blocks": int(mins.shape[0]),
                             "axes": ",".join(axes)})
    fn = _sharded_batch_reader(mesh, axes, partition_size,
                               _USE_KERNELS, _INTERPRET)
    lohi = np.asarray(lohi, np.int32).reshape(-1, 2)
    return fn(mins, keys, proj, bad,
              jnp.asarray(np.asarray(use_index), jnp.int32),
              jnp.asarray(lohi))


def attention(q, k, v, *, causal=True, window=None):
    if _USE_KERNELS:
        return flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_INTERPRET)
    return ref.attention(q, k, v, causal=causal, window=window)
