"""PAX range-scan Pallas kernel — the HailRecordReader inner loop (§4.3).

Streams partitions of a PAX block HBM->VMEM: for each row tile, evaluate the
clustered-key range predicate, emit the qualifying mask, the masked
projection columns, and a per-tile qualifying count (the caller's compaction
/ tuple-reconstruction gather uses the mask).  The caller passes only the
partition range [row_start, row_end) the index lookup selected — the kernel
never touches the rest of the block (that is the index-scan I/O win).

Grid: (row_tiles,); key tile (TR,) and projection tile (TR, C) in VMEM;
(lo, hi) are RUNTIME scalars in SMEM — one compiled kernel serves every
query range (the fused split reader in hail_reader.py subsumes this kernel
for whole-split reads; this stays as the single-block primitive).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(lohi_ref, key_ref, proj_ref, mask_ref, out_ref, cnt_ref):
    lo = lohi_ref[0, 0]
    hi = lohi_ref[0, 1]
    keys = key_ref[...]                       # (TR,)
    m = (keys >= lo) & (keys <= hi)
    mask_ref[...] = m
    out_ref[...] = jnp.where(m[:, None], proj_ref[...], 0)
    cnt_ref[0] = m.sum(dtype=jnp.int32)


def pax_scan(key_col: jax.Array, proj: jax.Array, lo, hi,
             *, row_tile: int = 1024, interpret: bool = True):
    """key_col (rows,), proj (rows, C) -> (mask (rows,), masked proj, counts).
    lo/hi may be python ints or traced values (no per-query recompile).
    """
    rows = key_col.shape[0]
    c = proj.shape[1]
    tr = min(row_tile, rows)
    while rows % tr:
        tr -= 1
    lohi = jnp.asarray([lo, hi], jnp.int32).reshape(1, 2)
    mask, out, cnt = pl.pallas_call(
        _scan_kernel,
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0),
                               memory_space=pltpu.SMEM),
                  pl.BlockSpec((tr,), lambda i: (i,)),
                  pl.BlockSpec((tr, c), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tr,), lambda i: (i,)),
                   pl.BlockSpec((tr, c), lambda i: (i, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((rows,), jnp.bool_),
                   jax.ShapeDtypeStruct((rows, c), proj.dtype),
                   jax.ShapeDtypeStruct((rows // tr,), jnp.int32)],
        interpret=interpret,
    )(lohi, key_col, proj)
    return mask, out, cnt
