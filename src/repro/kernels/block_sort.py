"""Bitonic sort-by-key Pallas kernel — the upload pipeline's per-replica sort.

TPU adaptation of the paper's in-RAM block sort (§3.5): the whole key column
of one HDFS block (power-of-two rows, <=64k) plus a row-index vector sit in
VMEM; the bitonic network runs entirely on the VPU using reshape/reverse/
select compare-exchanges (a ``pos ^ j`` partner exchange for power-of-two j
is exactly a reversal over a (n/2j, 2, j) view — no gathers needed).  The
emitted permutation then reorders every PAX column with one gather per
column (ops.sort_block).

Grid: one program per block; BlockSpec keeps key+perm tiles resident across
all O(log^2 n) stages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys, perm, j: int, k: int):
    """One bitonic stage: partner = pos ^ j, ascending iff (pos & k) == 0."""
    n = keys.shape[0]
    a_k = keys.reshape(n // (2 * j), 2, j)
    a_p = perm.reshape(n // (2 * j), 2, j)
    lo_k, hi_k = a_k[:, 0, :], a_k[:, 1, :]
    lo_p, hi_p = a_p[:, 0, :], a_p[:, 1, :]
    # ascending iff (group_base & k) == 0; constant within each 2j group
    base = jax.lax.broadcasted_iota(jnp.int32, (n // (2 * j), 1), 0) * (2 * j)
    asc = (base & k) == 0
    # Lexicographic (key, perm) comparator: perm starts as iota, so ties
    # break on original position — the network sorts a distinct composite
    # key, making the emitted permutation STABLE.  Stability matters for
    # corruption repair: a repaired block must reproduce the layout of a
    # fresh eager upload (jnp stable argsort) bit-for-bit so its recomputed
    # checksums match a healthy replica's.
    gt = (lo_k > hi_k) | ((lo_k == hi_k) & (lo_p > hi_p))
    lt = (lo_k < hi_k) | ((lo_k == hi_k) & (lo_p < hi_p))
    swap = jnp.where(asc, gt, lt)
    new_lo_k = jnp.where(swap, hi_k, lo_k)
    new_hi_k = jnp.where(swap, lo_k, hi_k)
    new_lo_p = jnp.where(swap, hi_p, lo_p)
    new_hi_p = jnp.where(swap, lo_p, hi_p)
    keys = jnp.stack([new_lo_k, new_hi_k], axis=1).reshape(n)
    perm = jnp.stack([new_lo_p, new_hi_p], axis=1).reshape(n)
    return keys, perm


def _bitonic_kernel(key_ref, out_key_ref, out_perm_ref, *, n: int):
    keys = key_ref[0, :]
    perm = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            keys, perm = _compare_exchange(keys, perm, j, k)
            j //= 2
        k *= 2
    out_key_ref[0, :] = keys
    out_perm_ref[0, :] = perm


def bitonic_sort(keys: jax.Array, *, interpret: bool = True):
    """keys (blocks, n) int32, n a power of two -> (sorted, perm)."""
    blocks, n = keys.shape
    assert n & (n - 1) == 0, f"rows must be a power of two, got {n}"
    kernel = functools.partial(_bitonic_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, n), lambda b: (b, 0))],
        out_specs=[pl.BlockSpec((1, n), lambda b: (b, 0)),
                   pl.BlockSpec((1, n), lambda b: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct((blocks, n), keys.dtype),
                   jax.ShapeDtypeStruct((blocks, n), jnp.int32)],
        interpret=interpret,
    )(keys)
