"""Flash attention (forward) Pallas kernel — the serving-prefill hot spot.

Tiled online-softmax attention with causal and sliding-window masking.
Grid (batch*kv_heads*rep, q_tiles, kv_tiles): the kv axis is the innermost
(sequential on TPU) grid dimension; running max/denominator/accumulator live
in VMEM scratch across kv steps and the output tile is written on the last
step.  Block sizes are MXU-aligned (multiples of 128 on the seq dims).

GQA is handled by indexing: program (b, g, r) reads q head g*rep+r and kv
head g — no materialized head repetition.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, window,
                  block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    s = q @ k.T                                       # (BQ, BK)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + p @ v
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q (B,T,H,D), k/v (B,S,KV,D), H = KV*rep -> (B,T,H,D)."""
    b, t, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    assert h == kvh * rep
    bq = min(block_q, t)
    bk = min(block_k, s)
    assert t % bq == 0 and s % bk == 0, (t, bq, s, bk)

    # layout: programs over (b*h); q head g*rep+r maps to kv head g
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)

    grid = (b * h, t // bq, s // bk)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), causal=causal,
        window=window, block_q=bq, block_k=bk, n_k=s // bk)

    def kv_index(bh, qi, ki):
        # program bh = batch*h + head; its kv row is batch*kvh + head//rep
        return ((bh // h) * kvh + (bh % h) // rep, ki, 0)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denominator
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)
