"""Fused Mamba1 selective-scan Pallas kernel.

§Perf iteration 8 showed the software formulations of the per-channel SSM
recurrence are HBM-bound either way on the XLA path: the associative scan
touches every (B,T,d,N) element O(log Tc) times, and a serial lax.scan pays
transposes + autodiff residuals.  The TPU-native answer mirrors the CUDA
kernel the Mamba authors wrote: FUSE the recurrence — stream (delta, x, B,
C) tiles HBM->VMEM once, keep the (d_block, N) state resident in VMEM
across the whole sequence, expand a_t/b_t in registers, and write only y
(and the final state) back.  HBM traffic drops from O(T*d*N*log Tc) to the
irreducible O(T*(2d + 2N)) input + O(T*d) output stream.

Grid: (batch, d_blocks, n_chunks); the chunk axis is innermost/sequential,
carrying the state scratch.  Time steps inside a chunk run in a
fori_loop over VMEM-resident tiles — the dependency chain is hidden by the
(d_block, N) lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(delta_ref, x_ref, b_ref, c_ref, a_ref, y_ref, hout_ref,
                 h_scr, *, tc: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a_log = a_ref[...]                       # (dblk, N) = -exp(A_log)
    delta = delta_ref[0]                     # (Tc, dblk)
    x = x_ref[0]                             # (Tc, dblk)
    bmat = b_ref[0]                          # (Tc, N)
    cmat = c_ref[0]                          # (Tc, N)

    def step(t, carry):
        h, y = carry
        dt_t = delta[t][:, None]             # (dblk, 1)
        at = jnp.exp(dt_t * a_log)           # (dblk, N)
        bt = (dt_t * x[t][:, None]) * bmat[t][None, :]
        h = at * h + bt
        y = y.at[t].set((h * cmat[t][None, :]).sum(axis=1))
        return h, y

    y0 = jnp.zeros(y_ref.shape[1:], jnp.float32)
    h, y = jax.lax.fori_loop(0, tc, step, (h_scr[...], y0))
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = h

    @pl.when(ci == n_chunks - 1)
    def _done():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan(delta: jax.Array, x: jax.Array, b: jax.Array,
                   c: jax.Array, a: jax.Array, *, chunk: int = 64,
                   d_block: int = 128, interpret: bool = True):
    """Mamba1 recurrence  h_t = exp(delta_t * A) h_{t-1} + delta_t B_t x_t,
    y_t = (h_t * C_t).sum(-1).

    delta, x: (B, T, D) f32; b, c: (B, T, N) f32; a: (D, N) f32 (negative).
    Returns y (B, T, D), h_final (B, D, N).
    """
    bs, t, d = delta.shape
    n = b.shape[-1]
    tc = min(chunk, t)
    while t % tc:
        tc -= 1
    dblk = min(d_block, d)
    while d % dblk:
        dblk -= 1
    n_chunks = t // tc
    grid = (bs, d // dblk, n_chunks)
    kernel = functools.partial(_scan_kernel, tc=tc, n_chunks=n_chunks)
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, dblk), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, tc, dblk), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, tc, n), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((1, tc, n), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((dblk, n), lambda bi, di, ci: (di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tc, dblk), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, dblk, n), lambda bi, di, ci: (bi, di, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bs, t, d), delta.dtype),
                   jax.ShapeDtypeStruct((bs, d, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((dblk, n), jnp.float32)],
        interpret=interpret,
    )(delta, x, b, c, a)
    return y, h_final
