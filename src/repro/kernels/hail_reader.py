"""Fused HAIL record-reader Pallas kernel: ONE dispatch per split.

This is HailSplitting (paper §4.3) applied inside the TPU runtime.  The
per-block pipeline used to be two kernels + a Python loop — ``index_search``
over the root directories, then one ``pax_scan`` launch per block.  That
re-created the exact per-task overhead the paper kills (3,200 map tasks ->
~20 splits, Fig 6c): every block paid a kernel dispatch, and every new
query range paid a recompile because (lo, hi) were baked in as Python ints.

Here the whole split is a single ``pallas_call`` with a 2D grid over
``(block, row_tile)``:

* the per-block ROOT DIRECTORY (partition minima) rides along in VMEM; each
  grid step recomputes the block's qualifying partition range with the same
  popcount-of-(mins <= v) reduction ``index_search`` used — a VPU reduction
  is far cheaper than a second dispatch;
* (lo, hi) live in SMEM as RUNTIME scalars, so one compiled reader serves
  every query against the same store shape — zero per-query recompiles;
* row tiles fully outside the partition range are PRUNED: predicated via
  ``pl.when``, they write zeros and skip the predicate/projection work (the
  index-scan I/O win, expressed as skipped compute per tile);
* per-block ``use_index`` flags let one dispatch serve MIXED splits — blocks
  whose chosen replica has a matching clustered index scan only their
  partition range, failover blocks full-scan — so the re-planned retry
  splits of a failed node run through the same fused kernel;
* outputs: qualifying mask (bad rows excluded), masked projection, and the
  per-block rows-read fraction feeding the I/O cost model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _reader_kernel(lohi_ref, mins_ref, keys_ref, proj_ref, bad_ref, uidx_ref,
                   mask_ref, out_ref, frac_ref, *,
                   partition_size: int, rows: int, row_tile: int):
    t = pl.program_id(1)
    lo = lohi_ref[0, 0]
    hi = lohi_ref[0, 1]

    # --- fused index_search: root-directory lookup for THIS block ----------
    mins = mins_ref[...]                                     # (1, P)
    p_first = jnp.maximum(jnp.sum(mins <= lo).astype(jnp.int32) - 1, 0)
    p_last = jnp.maximum(jnp.sum(mins <= hi).astype(jnp.int32) - 1, 0)
    use_index = uidx_ref[0] > 0
    r0 = jnp.where(use_index, p_first * partition_size, 0)
    r1 = jnp.where(use_index,
                   jnp.minimum((p_last + 1) * partition_size, rows), rows)

    # --- per-block rows-read fraction (once, at the first row tile) --------
    @pl.when(t == 0)
    def _():
        frac_ref[0] = (r1 - r0).astype(jnp.float32) / rows

    # --- row-tile scan, pruned outside [r0, r1) ----------------------------
    tile_lo = t * row_tile
    live = (tile_lo < r1) & (tile_lo + row_tile > r0)

    @pl.when(live)
    def _():
        keys = keys_ref[0, :]                                # (TR,)
        r = tile_lo + jax.lax.broadcasted_iota(jnp.int32, (row_tile, 1),
                                               0)[:, 0]
        in_range = (r >= r0) & (r < r1)
        m = (keys >= lo) & (keys <= hi) & in_range & ~bad_ref[0, :]
        mask_ref[0, :] = m
        out_ref[0, :, :] = jnp.where(m[:, None], proj_ref[0, :, :], 0)

    @pl.when(~live)                                          # pruned tile
    def _():
        mask_ref[0, :] = jnp.zeros((row_tile,), jnp.bool_)
        out_ref[0, :, :] = jnp.zeros_like(out_ref[0, :, :])


def hail_read(mins: jax.Array, keys: jax.Array, proj: jax.Array,
              bad: jax.Array, use_index: jax.Array, lo, hi, *,
              partition_size: int, row_tile: int = 1024,
              interpret: bool = True):
    """Fused split reader — one pallas_call for all blocks of a split.

    mins (B, P) int32       per-block root directories (ignored where
                            ``use_index`` is 0)
    keys (B, R) int32       filter column, replica-chosen per block
    proj (B, R, C)          projection columns (+rowid), same replicas
    bad  (B, R) bool        bad-record positions per block
    use_index (B,) int32    1 = clustered index matches -> partition pruning
    lo, hi                  RUNTIME scalars (python ints or traced values)

    -> (mask (B, R) bool, masked proj (B, R, C), rows_read_frac (B,) f32)
    """
    b, rows = keys.shape
    c = proj.shape[2]
    tr = min(row_tile, rows)
    while rows % tr:
        tr -= 1
    n_tiles = rows // tr
    lohi = jnp.asarray([lo, hi], jnp.int32).reshape(1, 2)
    import functools
    kernel = functools.partial(_reader_kernel, partition_size=partition_size,
                               rows=rows, row_tile=tr)
    mask, out, frac = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, t: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, mins.shape[1]), lambda i, t: (i, 0)),
            pl.BlockSpec((1, tr), lambda i, t: (i, t)),
            pl.BlockSpec((1, tr, c), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, tr), lambda i, t: (i, t)),
            pl.BlockSpec((1,), lambda i, t: (i,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, tr), lambda i, t: (i, t)),
            pl.BlockSpec((1, tr, c), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1,), lambda i, t: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, rows), jnp.bool_),
            jax.ShapeDtypeStruct((b, rows, c), proj.dtype),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(lohi, mins, keys, proj, bad, use_index.astype(jnp.int32))
    return mask, out, frac
