"""Fused HAIL record-reader Pallas kernel: ONE dispatch per split — and,
since the HailServer, ONE dispatch per (split, query-batch).

This is HailSplitting (paper §4.3) applied inside the TPU runtime.  The
per-block pipeline used to be two kernels + a Python loop — ``index_search``
over the root directories, then one ``pax_scan`` launch per block.  That
re-created the exact per-task overhead the paper kills (3,200 map tasks ->
~20 splits, Fig 6c): every block paid a kernel dispatch, and every new
query range paid a recompile because (lo, hi) were baked in as Python ints.

Here the whole split is a single ``pallas_call`` with a 2D grid over
``(block, row_tile)``:

* the per-block ROOT DIRECTORY (partition minima) rides along in VMEM; each
  grid step recomputes the block's qualifying partition range with the same
  popcount-of-(mins <= v) reduction ``index_search`` used — a VPU reduction
  is far cheaper than a second dispatch;
* the query ranges live in SMEM as a RUNTIME ``(Q, 2)`` lo/hi array, so one
  compiled reader serves every query — and every BATCH of Q concurrent
  queries — against the same store shape, with zero per-query recompiles.
  Q is static (it shapes the mask output), so a server batching at a fixed
  ``max_batch`` compiles one extra variant per distinct batch size, once;
* each grid step evaluates ALL Q range predicates against the one key tile
  it already loaded — the shared-scan win: Q concurrent range queries over
  a split cost one dispatch and one pass over the data instead of Q;
* row tiles outside EVERY query's partition range are PRUNED: predicated
  via ``pl.when``, they write zeros and skip the predicate/projection work
  (the index-scan I/O win, expressed as skipped compute per tile);
* per-block ``use_index`` flags let one dispatch serve MIXED splits — blocks
  whose chosen replica has a matching clustered index scan only their
  partition range, failover blocks full-scan — so the re-planned retry
  splits of a failed node run through the same fused kernel;
* outputs: a PER-QUERY qualifying mask (bad rows excluded), the projection
  masked by the UNION of the query masks (rows no query wants stay zero;
  each query recovers its own rows via its mask), and per-(block, query)
  rows-read fractions feeding the I/O cost model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _reader_kernel(lohi_ref, mins_ref, keys_ref, proj_ref, bad_ref, uidx_ref,
                   mask_ref, out_ref, frac_ref, *,
                   partition_size: int, rows: int, row_tile: int, n_q: int):
    t = pl.program_id(1)

    # --- fused index_search: root-directory lookup for THIS block, once per
    # query (n_q is static — the loop unrolls into n_q VPU reductions) ------
    mins = mins_ref[...]                                     # (1, P)
    use_index = uidx_ref[0] > 0
    tile_lo = t * row_tile
    r0s, r1s, lives = [], [], []
    for qi in range(n_q):
        lo = lohi_ref[qi, 0]
        hi = lohi_ref[qi, 1]
        p_first = jnp.maximum(jnp.sum(mins <= lo).astype(jnp.int32) - 1, 0)
        p_last = jnp.maximum(jnp.sum(mins <= hi).astype(jnp.int32) - 1, 0)
        r0 = jnp.where(use_index, p_first * partition_size, 0)
        r1 = jnp.where(use_index,
                       jnp.minimum((p_last + 1) * partition_size, rows), rows)
        r0s.append(r0)
        r1s.append(r1)
        lives.append((tile_lo < r1) & (tile_lo + row_tile > r0))

    # --- per-(block, query) rows-read fraction (once, at the first tile) ---
    @pl.when(t == 0)
    def _():
        for qi in range(n_q):
            frac_ref[0, qi] = (r1s[qi] - r0s[qi]).astype(jnp.float32) / rows

    # --- row-tile scan, pruned when the tile is dead for EVERY query -------
    live_any = lives[0]
    for lv in lives[1:]:
        live_any = live_any | lv

    @pl.when(live_any)
    def _():
        keys = keys_ref[0, :]                                # (TR,)
        r = tile_lo + jax.lax.broadcasted_iota(jnp.int32, (row_tile, 1),
                                               0)[:, 0]
        good = ~bad_ref[0, :]
        any_m = jnp.zeros((row_tile,), jnp.bool_)
        for qi in range(n_q):
            lo = lohi_ref[qi, 0]
            hi = lohi_ref[qi, 1]
            in_range = (r >= r0s[qi]) & (r < r1s[qi])
            m = (keys >= lo) & (keys <= hi) & in_range & good
            mask_ref[0, :, qi] = m
            any_m = any_m | m
        out_ref[0, :, :] = jnp.where(any_m[:, None], proj_ref[0, :, :], 0)

    @pl.when(~live_any)                                      # pruned tile
    def _():
        mask_ref[0, :, :] = jnp.zeros((row_tile, n_q), jnp.bool_)
        out_ref[0, :, :] = jnp.zeros_like(out_ref[0, :, :])


def hail_read_batch(mins: jax.Array, keys: jax.Array, proj: jax.Array,
                    bad: jax.Array, use_index: jax.Array, lohi: jax.Array, *,
                    partition_size: int, row_tile: int = 1024,
                    interpret: bool = True):
    """Fused shared-scan reader — one pallas_call for all blocks of a split
    AND all Q queries of a batch.

    mins (B, P) int32       per-block root directories (ignored where
                            ``use_index`` is 0)
    keys (B, R) int32       filter column, replica-chosen per block
    proj (B, R, C)          projection columns (+rowid), same replicas
    bad  (B, R) bool        bad-record positions per block
    use_index (B,) int32    1 = clustered index matches -> partition pruning
    lohi (Q, 2) int32       RUNTIME per-query (lo, hi) ranges in SMEM

    -> (mask (B, R, Q) bool — per-query match masks,
        proj masked by the union of the Q masks (B, R, C),
        rows_read_frac (B, Q) f32)
    """
    b, rows = keys.shape
    c = proj.shape[2]
    n_q = lohi.shape[0]
    tr = min(row_tile, rows)
    while rows % tr:
        tr -= 1
    n_tiles = rows // tr
    kernel = functools.partial(_reader_kernel, partition_size=partition_size,
                               rows=rows, row_tile=tr, n_q=n_q)
    mask, out, frac = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((n_q, 2), lambda i, t: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, mins.shape[1]), lambda i, t: (i, 0)),
            pl.BlockSpec((1, tr), lambda i, t: (i, t)),
            pl.BlockSpec((1, tr, c), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, tr), lambda i, t: (i, t)),
            pl.BlockSpec((1,), lambda i, t: (i,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, tr, n_q), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, tr, c), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, n_q), lambda i, t: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, rows, n_q), jnp.bool_),
            jax.ShapeDtypeStruct((b, rows, c), proj.dtype),
            jax.ShapeDtypeStruct((b, n_q), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(lohi, jnp.int32), mins, keys, proj, bad,
      use_index.astype(jnp.int32))
    return mask, out, frac


def hail_read(mins: jax.Array, keys: jax.Array, proj: jax.Array,
              bad: jax.Array, use_index: jax.Array, lo, hi, *,
              partition_size: int, row_tile: int = 1024,
              interpret: bool = True):
    """Single-query fused split reader: the Q=1 case of ``hail_read_batch``.

    -> (mask (B, R) bool, masked proj (B, R, C), rows_read_frac (B,) f32)
    """
    lohi = jnp.asarray([lo, hi], jnp.int32).reshape(1, 2)
    mask, out, frac = hail_read_batch(mins, keys, proj, bad, use_index, lohi,
                                      partition_size=partition_size,
                                      row_tile=row_tile, interpret=interpret)
    return mask[..., 0], out, frac[:, 0]
