"""Index-search Pallas kernel: the clustered index's root-directory lookup.

For a batch of blocks, each with a VMEM-resident root of sorted partition
minima, find [p_first, p_last] for a (lo, hi) range (paper Fig 2 steps 1+2).
Roots are sorted, so searchsorted == popcount of (mins <= v) — one VPU
reduction instead of a serial binary search (TPU adaptation: data-parallel
counting beats branchy log-time search on a vector unit).

Grid tiles the block axis; (lo, hi) are RUNTIME scalars in SMEM, so one
compiled kernel serves every query range.  The fused split reader
(hail_reader.py) inlines this lookup per grid step; this standalone kernel
remains the batched root-lookup primitive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _search_kernel(lohi_ref, mins_ref, out_ref):
    lo = lohi_ref[0, 0]
    hi = lohi_ref[0, 1]
    mins = mins_ref[...]                                    # (TB, P)
    first = jnp.maximum(jnp.sum(mins <= lo, axis=1).astype(jnp.int32) - 1, 0)
    last = jnp.maximum(jnp.sum(mins <= hi, axis=1).astype(jnp.int32) - 1, 0)
    out_ref[...] = jnp.stack([first, last], axis=1)


def index_search(mins: jax.Array, lo, hi,
                 *, block_tile: int = 8, interpret: bool = True) -> jax.Array:
    """mins (blocks, n_parts) sorted rows -> (blocks, 2) int32.
    lo/hi may be python ints or traced values (no per-query recompile)."""
    blocks, n_parts = mins.shape
    tb = min(block_tile, blocks)
    while blocks % tb:
        tb -= 1
    lohi = jnp.asarray([lo, hi], jnp.int32).reshape(1, 2)
    return pl.pallas_call(
        _search_kernel,
        grid=(blocks // tb,),
        in_specs=[pl.BlockSpec((1, 2), lambda b: (0, 0),
                               memory_space=pltpu.SMEM),
                  pl.BlockSpec((tb, n_parts), lambda b: (b, 0))],
        out_specs=pl.BlockSpec((tb, 2), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, 2), jnp.int32),
        interpret=interpret,
    )(lohi, mins)
