"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def sort_by_key(keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (sorted_keys, permutation). keys (n,) int32."""
    perm = jnp.argsort(keys)
    return keys[perm], perm.astype(jnp.int32)


def index_search(mins: jax.Array, lo, hi) -> jax.Array:
    """mins (blocks, n_parts) sorted -> (blocks, 2) [p_first, p_last]."""
    first = jnp.maximum(
        jnp.sum(mins <= lo, axis=-1).astype(jnp.int32) - 1, 0)
    last = jnp.maximum(
        jnp.sum(mins <= hi, axis=-1).astype(jnp.int32) - 1, 0)
    return jnp.stack([first, last], axis=-1)


def pax_scan(key_col: jax.Array, proj: jax.Array, lo, hi):
    """key_col (rows,), proj (rows, n_proj) -> (mask, masked_proj, count)."""
    mask = (key_col >= lo) & (key_col <= hi)
    out = jnp.where(mask[:, None], proj, 0)
    return mask, out, mask.sum(dtype=jnp.int32)


def hail_read(mins, keys, proj, bad, use_index, lo, hi, *,
              partition_size: int):
    """Fused split-reader oracle: per-block root lookup + pruned range scan.

    mins (B,P), keys (B,R), proj (B,R,C), bad (B,R) bool, use_index (B,)
    -> (mask (B,R) bool, masked proj, rows_read_frac (B,) f32)."""
    rows = keys.shape[1]
    pr = index_search(mins, lo, hi)                          # (B, 2)
    r0 = jnp.where(use_index > 0, pr[:, 0] * partition_size, 0)
    r1 = jnp.where(use_index > 0,
                   jnp.minimum((pr[:, 1] + 1) * partition_size, rows), rows)
    r = jnp.arange(rows, dtype=jnp.int32)[None, :]
    in_range = (r >= r0[:, None]) & (r < r1[:, None])
    mask = (keys >= lo) & (keys <= hi) & in_range & ~bad
    out = jnp.where(mask[..., None], proj, 0)
    frac = (r1 - r0).astype(jnp.float32) / rows
    return mask, out, frac


def hail_read_batch(mins, keys, proj, bad, use_index, lohi, *,
                    partition_size: int):
    """Shared-scan batch oracle: Q range queries over one split at once.

    lohi (Q, 2) -> (mask (B, R, Q) bool, proj masked by the union of the Q
    masks (B, R, C), rows_read_frac (B, Q) f32) — the Q=1 slice matches
    ``hail_read`` exactly."""

    def one(lo, hi):
        m, _, f = hail_read(mins, keys, proj, bad, use_index, lo, hi,
                            partition_size=partition_size)
        return m, f

    mask_q, frac_q = jax.vmap(one)(lohi[:, 0], lohi[:, 1])   # (Q,B,R) (Q,B)
    mask = jnp.moveaxis(mask_q, 0, -1)                       # (B, R, Q)
    out = jnp.where(mask.any(axis=-1)[..., None], proj, 0)
    return mask, out, jnp.moveaxis(frac_q, 0, -1)


def selective_scan(delta, x, b, c, a):
    """Naive mamba1 recurrence oracle.  delta,x (B,T,D); b,c (B,T,N);
    a (D,N) negative. -> y (B,T,D), h_final (B,D,N)."""

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp              # (B,D) (B,D) (B,N) (B,N)
        at = jnp.exp(dt_t[..., None] * a)      # (B,D,N)
        bt = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = at * h + bt
        y = (h * c_t[:, None, :]).sum(-1)      # (B,D)
        return h, y

    bs, t, d = delta.shape
    h0 = jnp.zeros((bs, d, a.shape[-1]), jnp.float32)
    inp = (delta.swapaxes(0, 1), x.swapaxes(0, 1),
           b.swapaxes(0, 1), c.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, inp)
    return ys.swapaxes(0, 1), h


def attention(q, k, v, *, causal: bool = True, window: int | None = None):
    """q (B,T,H,D), k/v (B,S,KV,D) -> (B,T,H,D). fp32 softmax oracle."""
    b, t, h, d = q.shape
    s, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, t, kvh, rep, d).astype(jnp.float32)
    sc = jnp.einsum("btgrk,bsgk->bgrts", qg, k.astype(jnp.float32))
    sc = sc / math.sqrt(d)
    qp = jnp.arange(t)[:, None]
    kp = jnp.arange(s)[None, :]
    m = jnp.ones((t, s), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    sc = jnp.where(m, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bgrts,bsgk->btgrk", w, v.astype(jnp.float32))
    return out.reshape(b, t, h, d).astype(q.dtype)
