"""HailSplitting (paper §4.3, §6.5).

Hadoop default: one input split per block -> one map task per block; each
task pays constant scheduling overhead, which dominates short (index-scan)
tasks — the paper measured jobs where overhead was ~95% of runtime (Fig 6c).

HailSplitting, for index-scan jobs: cluster the job's blocks by the datanode
holding the chosen replica, then emit ``map_slots`` splits per node, each
covering MANY blocks.  3,200 tasks became 20 in the paper (68x end-to-end).
For full-scan jobs the default per-block splitting is kept (failover story
unchanged).

The TPU-framework analogue is real: one dispatch per *split* instead of one
per *block*.  The jnp record reader batches all of a split's blocks into one
jit call, and the fused Pallas reader (kernels/hail_reader.py) executes a
whole split — index lookup, tile-pruned scan, projection — as a SINGLE
``pallas_call`` with a 2D (block, row_tile) grid, even when the split mixes
index-scan and failover full-scan blocks.  ``run_job`` then dispatches every
split asynchronously before one completion barrier, so split execution
pipelines; the per-task scheduling constant in EXPERIMENTS.md is the only
remaining per-split cost, exactly the paper's framing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.query import QueryPlan
from repro.core.store import BlockStore


@dataclasses.dataclass(frozen=True)
class Split:
    node: int
    block_ids: tuple[int, ...]
    index_scan: bool


def hadoop_splits(store: BlockStore, qplan: QueryPlan) -> list[Split]:
    """Default policy: one split per block."""
    return [Split(node=int(qplan.nodes[b]), block_ids=(b,),
                  index_scan=bool(qplan.index_scan[b]))
            for b in range(store.n_blocks)]


def hail_splits(store: BlockStore, qplan: QueryPlan,
                map_slots: int = 4) -> list[Split]:
    if not qplan.index_scan.all():
        # full-scan (or mixed) job: keep Hadoop's per-block splitting for the
        # scan part, coalesce only the indexed part
        idx_blocks = np.nonzero(qplan.index_scan)[0]
        scan_blocks = np.nonzero(~qplan.index_scan)[0]
        out = [Split(int(qplan.nodes[b]), (int(b),), False)
               for b in scan_blocks]
        out += _coalesce(idx_blocks, qplan, map_slots)
        return out
    return _coalesce(np.arange(store.n_blocks), qplan, map_slots)


def _coalesce(blocks: np.ndarray, qplan: QueryPlan,
              map_slots: int) -> list[Split]:
    splits: list[Split] = []
    for node in np.unique(qplan.nodes[blocks]):
        mine = blocks[qplan.nodes[blocks] == node]
        n_splits = min(map_slots, len(mine))
        for part in np.array_split(mine, n_splits):
            if len(part):
                splits.append(Split(node=int(node),
                                    block_ids=tuple(int(b) for b in part),
                                    index_scan=True))
    return splits
