"""Schemas for the HAIL block store.

A *logical row* is a tuple of typed attributes.  A *block* holds a fixed
number of rows in PAX (column-major) layout: one JAX array per column.  An
implicit ``__rowid__`` column (original upload position) is carried through
every per-replica sort so any replica can reconstruct the logical block —
the paper's failover invariant, property-tested in tests/test_hail_core.py.

Fixed-width ASCII encoding (for the upload parse stage): each column is a
zero-padded decimal of ``ascii_width`` chars; a row is the concatenation plus
a newline.  Floats are stored as scaled integers (cents).  This mirrors the
paper's text-log inputs while staying vectorizable.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

ROWID = "__rowid__"


@dataclasses.dataclass(frozen=True)
class Column:
    name: str
    dtype: object = jnp.int32
    ascii_width: int = 10          # chars in the text encoding
    scale: float = 1.0             # value = int / scale (adRevenue cents)


@dataclasses.dataclass(frozen=True)
class Schema:
    name: str
    columns: tuple[Column, ...]

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    @property
    def row_ascii_width(self) -> int:
        return sum(c.ascii_width for c in self.columns) + 1  # + newline

    def col(self, name: str) -> Column:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(name)

    def index_of(self, name: str) -> int:
        return self.names.index(name)


# The paper's UserVisits table (Pavlo et al. [27]); strings dictionary-encoded.
USERVISITS = Schema("UserVisits", (
    Column("sourceIP"),                 # IPv4 packed to int32
    Column("destURL"),                  # dictionary id
    Column("visitDate"),                # days since epoch
    Column("adRevenue", scale=100.0),   # cents
    Column("userAgent"),                # dictionary id
    Column("countryCode"),
    Column("languageCode"),
    Column("searchWord"),               # dictionary id
    Column("duration"),
))

# The paper's Synthetic dataset: 19 integer attributes.
SYNTHETIC = Schema("Synthetic",
                   tuple(Column(f"attr{i}") for i in range(19)))


def tokens_schema(seq_width: int = 0) -> Schema:
    """LM-training corpus blocks: selection attributes + token payload ids.

    Token payloads are stored as ``seq_width`` extra columns (tok0..tokN) so
    the whole row stays PAX-decomposable; HailDataSource reassembles (rows,
    seq_width) token matrices from qualifying rows.
    """
    cols = [Column("doc_id"), Column("domain"), Column("quality", scale=1000.0),
            Column("timestamp"), Column("length")]
    cols += [Column(f"tok{i}", ascii_width=6) for i in range(seq_width)]
    return Schema("TokensCorpus", tuple(cols))


# ---------------------------------------------------------------------------
# Synthetic data generation (host side, numpy)
# ---------------------------------------------------------------------------


def gen_uservisits(n_rows: int, seed: int = 0) -> dict[str, np.ndarray]:
    r = np.random.default_rng(seed)
    return {
        "sourceIP": r.integers(0, 2**31 - 1, n_rows, dtype=np.int32),
        "destURL": r.integers(0, 1_000_000, n_rows, dtype=np.int32),
        "visitDate": r.integers(7000, 12000, n_rows, dtype=np.int32),  # ~1989-2002
        "adRevenue": r.integers(0, 100_000, n_rows, dtype=np.int32),   # cents
        "userAgent": r.integers(0, 10_000, n_rows, dtype=np.int32),
        "countryCode": r.integers(0, 250, n_rows, dtype=np.int32),
        "languageCode": r.integers(0, 100, n_rows, dtype=np.int32),
        "searchWord": r.integers(0, 100_000, n_rows, dtype=np.int32),
        "duration": r.integers(0, 10_000, n_rows, dtype=np.int32),
    }


def gen_synthetic(n_rows: int, seed: int = 0) -> dict[str, np.ndarray]:
    r = np.random.default_rng(seed)
    return {f"attr{i}": r.integers(0, 2**20, n_rows, dtype=np.int32)
            for i in range(19)}


def gen_tokens_corpus(n_rows: int, seq_width: int, vocab: int = 50000,
                      n_domains: int = 16, seed: int = 0) -> dict[str, np.ndarray]:
    r = np.random.default_rng(seed)
    d = {
        "doc_id": np.arange(n_rows, dtype=np.int32),
        "domain": r.integers(0, n_domains, n_rows, dtype=np.int32),
        "quality": r.integers(0, 1000, n_rows, dtype=np.int32),
        "timestamp": r.integers(0, 1 << 20, n_rows, dtype=np.int32),
        "length": r.integers(seq_width // 2, seq_width, n_rows, dtype=np.int32),
    }
    for i in range(seq_width):
        d[f"tok{i}"] = r.integers(0, vocab, n_rows, dtype=np.int32)
    return d
