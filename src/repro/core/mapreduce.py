"""MapReduce engines over the HAIL block store.

Two executors:

* ``run_job`` — split-driven executor (the Hadoop-pipeline analogue): one
  jit dispatch per split (HailSplitting batches many blocks per dispatch);
  per-task overheads accounted explicitly (measured dispatch + configurable
  simulated scheduler constant, the paper's multi-second Hadoop overhead).
  Execution is ASYNC: all splits are dispatched up front (jax's async
  dispatch queues them) and a single completion pass blocks per result —
  split execution pipelines instead of serializing, with per-split timing
  preserved via dispatch/completion timestamps (``JobStats.split_s``).
  ``reader="kernels"`` routes pax splits through the fused one-dispatch
  ``read_hail_kernels`` Pallas reader.  Node-failure injection re-schedules
  a failed node's splits onto surviving replicas, falling back to full scan
  when the lost replica held the only matching index (paper Fig 8).
  ``adaptive=AdaptiveConfig(...)`` enables LAZY ADAPTIVE INDEXING ("Towards
  Zero-Overhead Adaptive Indexing in Hadoop"): full-scan splits additionally
  sort + index an offered fraction of their still-unindexed blocks — the
  bitonic ``kernels/block_sort`` does the in-kernel sort, the clustered root
  directory comes from ``core/index`` — and commit the result back into the
  ``BlockStore`` mid-job, so repeated jobs over the same store converge from
  all-full-scan to all-index-scan with no eager upload cost.

* ``spmd_aggregate`` — shard_map engine for cluster-wide aggregations:
  map+combine per device over the block-sharded store, hash-bucket shuffle
  via all_to_all, segment-sum reduce.  Degenerates gracefully on 1 device;
  lowerable on the 512-device production mesh (see tests).

Simulated-cluster constants and the dispatch-count model are documented in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checksum as ck
from repro.core import index as idx
from repro.core import query as q
from repro.core.fault import (CorruptBlockError, RecoveryConfig,
                              UnrecoverableDataError)
from repro.core.splitting import Split, hadoop_splits, hail_splits
from repro.core.store import BlockStore
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class JobStats:
    n_tasks: int
    map_compute_s: float       # dispatch-to-last-completion wall (pipelined)
    overhead_s: float          # dispatch + simulated scheduling
    bytes_read: int
    end_to_end_s: float        # compute + overhead (simulated cluster walltime)
    record_reader_s: float
    results: dict
    rescheduled_tasks: int = 0
    split_s: list = dataclasses.field(default_factory=list)
    # ^ per split: completion timestamp - its dispatch timestamp (includes
    #   queue wait behind earlier splits; the pipelining win shows as
    #   map_compute_s << sum(split_s))
    blocks_indexed: int = 0    # adaptive: indexes committed by THIS job
    index_build_s: float = 0.0 # measured wall spent building/committing them
    build_s: list = dataclasses.field(default_factory=list)
    # ^ per executed split, aligned with split_s: index-build wall piggy-
    #   backed on that split (0.0 for splits that offered nothing) —
    #   ``job_tasks`` bridges these into runtime/scheduler Tasks whose
    #   index_build_s is charged to the task's runtime
    full_scan_blocks: int = 0  # blocks this job read WITHOUT an index
    modeled_s: float = 0.0     # deterministic latency: scheduling + disk
    #   (no measured-compute term — the convergence-curve monotonicity
    #   guard asserts on this, immune to wall-clock noise)
    blocks_demoted: int = 0    # governor: per-block indexes dropped by THIS
    #   job's demotions (workload shift re-claiming / budget eviction)
    rekey_s: float = 0.0       # measured wall spent demoting (un-sorting +
    #   re-checksumming victims) — the re-key tax of a workload shift
    demote_s: list = dataclasses.field(default_factory=list)
    # ^ per executed split, aligned with split_s: demotion wall charged to
    #   the split that needed the room (0.0 otherwise) — bridged into
    #   scheduler Tasks via ``Task.rekey_s``, like build_s
    blocks_quarantined: int = 0  # corrupt (replica, block)s this job found
    corrupt_retries: int = 0     # splits re-planned after CorruptBlockError
    scrub_s: float = 0.0         # background-scrubber wall at the job
    #   boundary (verify + repair of quarantined blocks)


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Lazy adaptive indexing (LIAH) knobs.

    ``offer_rate``: fraction of the job's still-unindexed blocks offered for
    in-job index building — the per-job build budget is
    ``ceil(offer_rate * n_unindexed)`` (so an unindexed store converges in
    ~``ceil(1/offer_rate)`` jobs), spent by full-scan splits in dispatch
    order.  ``max_build_per_job`` caps the budget to bound the per-job
    latency tax of building.
    """
    offer_rate: float = 0.25
    max_build_per_job: int = 64


def _build_block_indexes(store: BlockStore, replica_id: int, block_ids,
                         key: str, *, partition_size: int) -> int:
    """Sort + index + commit ``block_ids`` of one replica by ``key``, as one
    batched dispatch per call (the ``kernels/block_sort`` bitonic network
    when rows is a power of two).  Bad records are forced to the tail with
    the INT32_MAX sentinel, exactly like the eager upload sort."""
    from repro.kernels import ops

    rep = store.replicas[replica_id]
    bsel = np.asarray(block_ids)
    if store.verify_reads and len(bsel):
        # verify BEFORE building: sorting corrupt bytes and committing them
        # would recompute valid checksums over garbage, laundering the
        # corruption past every future read-path check.  Failing blocks are
        # quarantined and dropped from the offer (one batched dispatch).
        names = sorted(rep.cols)
        data = jnp.stack([rep.cols[c][bsel] for c in names])
        sums = jnp.stack([rep.checksums[c][bsel] for c in names])
        okm = np.asarray(ops.verify_blocks(data, sums)).all(axis=0)
        for b in bsel[~okm]:
            store.quarantine_block(replica_id, int(b))
        bsel = bsel[okm]
        if len(bsel) == 0:
            return 0
    bad = q._bad_mask(store, replica_id)[bsel]     # pre-commit (upload order)
    sent = jnp.where(bad, jnp.iinfo(jnp.int32).max, rep.cols[key][bsel])
    cols = {c: v[bsel] for c, v in rep.cols.items()}
    _, sorted_cols, _ = ops.sort_block(sent, cols)
    mins = idx.build_block_roots(sorted_cols[key], partition_size)
    sums = {c: jax.vmap(ck.chunk_checksums)(v) for c, v in sorted_cols.items()}
    return store.commit_block_indexes(replica_id, bsel, key, sorted_cols,
                                      mins, sums)


def adaptive_quantum(store: BlockStore, adaptive: "AdaptiveConfig") -> int:
    """Per-job (or per-server-FLUSH) build budget: offer_rate of the store's
    blocks (not of the shrinking remainder), so an unindexed store converges
    in ceil(1/offer_rate) jobs — the EXPERIMENTS.md model.  The HailServer
    draws ONE quantum per flush and shares it across every tenant's batch,
    so concurrent traffic does not multiply the build tax."""
    return min(adaptive.max_build_per_job,
               int(np.ceil(adaptive.offer_rate * store.n_blocks)))


def claim_adaptive_replica(store: BlockStore, adapt_col: str,
                           quantum: int) -> tuple[Optional[int], int, float]:
    """Pick the replica to (keep) converging toward ``adapt_col``.

    When every replica is claimed by other keys, ask the governor for its
    LRU victim, demote it, and re-claim — splits already planned keep
    reading the demoted replica as a full scan (row-set preserved: upload
    order + original bad mask), so demoting under a live plan is safe.
    Gated on (a) a usable build quantum — a job that can't rebuild must not
    destroy an index for nothing — and (b) the governor's claim-time
    HYSTERESIS: the column must have missed in ``claim_miss_jobs`` distinct
    jobs (this one included), so a one-off query never evicts a warm index.

    Returns (replica_id or None, blocks demoted, demotion wall seconds).
    """
    governor = store.governor
    adapt_rid = store.adaptive_replica_for(adapt_col)
    demoted, d_wall = 0, 0.0
    if (adapt_rid is None and governor is not None and quantum > 0
            and governor.may_reclaim(store, adapt_col)):
        victim = governor.victim(store, protect=(adapt_col,))
        if victim is not None:
            t_d = time.perf_counter()
            demoted = store.demote_replica(victim)
            d_wall = time.perf_counter() - t_d
            obs_trace.complete_wall("demote", t_d, d_wall, track="adaptive",
                                    args={"replica": victim,
                                          "blocks": demoted,
                                          "reclaim_for": adapt_col})
            adapt_rid = store.adaptive_replica_for(adapt_col)
    return adapt_rid, demoted, d_wall


def piggyback_build(store: BlockStore, sp: "Split", adapt_rid: int,
                    adapt_col: str, build_budget: int
                    ) -> tuple[int, int, float, float]:
    """Adaptive piggyback for ONE full-scan split: this split already read
    its blocks — sort + index an offered few of the still-unindexed ones
    and commit them for the NEXT job (the split's own read was dispatched
    pre-commit).  Under budget pressure, evict LRU victims until the offer
    fits, else trim it (the budget is never exceeded).

    Returns (built, demoted, build wall seconds, demotion wall seconds).
    """
    governor = store.governor
    if build_budget <= 0 or sp.index_scan:
        return 0, 0, 0.0, 0.0
    rep = store.replicas[adapt_rid]
    dead = store.namenode.dead
    offer = [b for b in sp.block_ids
             if not rep.indexed[b]
             and int(rep.nodes[b]) not in dead
             and not store.is_quarantined(adapt_rid, b)][:build_budget]
    demoted, d_wall, b_wall = 0, 0.0, 0.0
    if offer and governor is not None:
        room = governor.room(store)
        while len(offer) > room:
            victim = governor.victim(store, protect=(adapt_col,))
            if victim is None:
                offer = offer[:max(int(room), 0)]
                break
            t_d = time.perf_counter()
            demoted += store.demote_replica(victim)
            d_wall += time.perf_counter() - t_d
            obs_trace.complete_wall("demote", t_d,
                                    time.perf_counter() - t_d,
                                    track="adaptive",
                                    args={"replica": victim,
                                          "reason": "budget"})
            room = governor.room(store)
    built = 0
    if offer:
        t_b = time.perf_counter()
        built = _build_block_indexes(store, adapt_rid, offer, adapt_col,
                                     partition_size=store.partition_size)
        b_wall = time.perf_counter() - t_b
        obs_trace.complete_wall("adaptive_build", t_b, b_wall,
                                track="adaptive",
                                args={"replica": adapt_rid,
                                      "column": adapt_col, "blocks": built})
    return built, demoted, b_wall, d_wall


def failover_replan(store: BlockStore, query: q.HailQuery,
                    pending: list, i: int):
    """Node-death re-plan, shared by ``run_job`` and the HailServer: kill
    the node serving ``pending[i]``, re-plan the NOT-yet-executed splits it
    owned onto surviving replicas as per-block retry splits (falling back
    to full scan when the lost replica held the only matching index), and
    splice them after the surviving pending splits.  Splits dispatched
    before the failure already ran — their results stand, exactly as
    completed map tasks do in Hadoop.

    Returns (new_pending, new_qplan, failed_node, n_retries).
    """
    failed_node = pending[i].node
    store.namenode.kill_node(failed_node)
    qplan = q.plan(store, query)
    survivors = [s for s in pending[i:] if s.node != failed_node]
    lost = [b for s in pending[i:] if s.node == failed_node
            for b in s.block_ids]
    retries = [Split(node=int(qplan.nodes[b]), block_ids=(b,),
                     index_scan=bool(qplan.index_scan[b])) for b in lost]
    return (pending[:i] + survivors + retries, qplan, failed_node,
            len(retries))


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """Simulated-cluster constants (documented in EXPERIMENTS.md)."""
    sched_overhead_s: float = 3.0      # Hadoop per-task scheduling (paper §6.4)
    hail_sched_overhead_s: float = 3.0 # same scheduler; fewer tasks is the win
    disk_bw: float = 100e6             # B/s (paper's 100MB/s disk)
    n_nodes: int = 10
    map_slots: int = 4


def job_tasks(stats: JobStats) -> list:
    """Bridge a finished job into the event-driven cluster simulator: one
    ``runtime/scheduler.Task`` per executed split, with the measured
    per-split read wall as the duration and the index-build wall the split
    piggybacked charged via ``Task.index_build_s`` (the scheduler adds it
    to the task's runtime, so convergence-era tasks are honestly slower —
    bench_adaptive reports the resulting makespans).  Governor demotions
    are charged the same way through ``Task.rekey_s`` — the split that
    triggered the eviction pays its un-sort/re-checksum wall."""
    from repro.runtime.scheduler import Task
    demote = stats.demote_s or [0.0] * len(stats.split_s)
    return [Task(i, dur, preferred_nodes=(), index_build_s=build,
                 rekey_s=rekey)
            for i, (dur, build, rekey) in enumerate(zip(stats.split_s,
                                                        stats.build_s,
                                                        demote))]


def run_job(store: BlockStore, query: q.HailQuery, *,
            splitting: str = "hail", cluster: ClusterModel = ClusterModel(),
            reduce_fn: Optional[Callable] = None,
            fail_node_at: Optional[float] = None,
            reader: str = "jnp",
            mesh=None,
            adaptive: Optional[AdaptiveConfig] = None,
            recovery: RecoveryConfig = RecoveryConfig(),
            on_split_complete: Optional[Callable] = None) -> JobStats:
    """Execute filter/project (+optional reduce) over all blocks.

    reader: 'jnp' (batched jnp record reader) or 'kernels' (fused Pallas
    split reader — one pallas_call dispatch per split; interpret mode on
    CPU, so 'jnp' stays the container default).

    mesh: a ``jax.sharding.Mesh`` to SHARD the scan over — splits are
    gathered host-side as usual (cache/verify/attribution per split,
    preserving serial semantics for piggyback commits and failover) but
    dispatched in WAVES of up to n_dev splits through ONE shard_map'd
    fused reader, each split's block tile on its own device (per-device
    fused dispatches = ceil(n_splits / n_dev)).  The scan axes come from
    ``dist.sharding.scan_mesh_axes`` (size-1 axes dropped); a mesh with no
    multi-device scan axis, a non-PAX store, or an unfiltered query falls
    back to the serial per-split path.  Row-sets are byte-identical to the
    single-device path.

    adaptive: when set (and the job filters a PAX store), full-scan splits
    piggyback clustered-index builds for an offered fraction of their
    unindexed blocks and commit them back into the store — this job's reads
    keep their dispatch-time plan; the NEXT job plans against the richer
    store.  Re-queued failover splits full-scan and are offered too.

    When the store carries an index governor (``governor.govern(store)``),
    adaptive jobs also DEMOTE: if every replica is claimed by other keys,
    the governor's LRU victim is dropped back to unclaimed so this workload
    can re-claim it; if committing an offer would exceed the storage
    budget, victims are evicted (or the offer trimmed) first.  Demotion
    walls are charged per split (``JobStats.demote_s``/``rekey_s``) and
    dropped indexes counted in ``JobStats.blocks_demoted``.

    recovery: corruption/failover retry policy.  A split whose read-path
    verification raises ``CorruptBlockError`` quarantines the corrupt
    (replica, block) at the namenode and re-plans the split's blocks onto
    surviving replicas as per-block retry splits — the same shape the
    node-failure path produces.  Retries are BOUNDED per block
    (``recovery.max_retries``, failover and corruption share the budget);
    exhausting it, or losing every replica of a block, raises
    ``UnrecoverableDataError`` — never silent wrong rows.  With
    ``recovery.scrub`` and a scrubber attached (``store.scrubber``), the
    job boundary also verifies a budgeted batch of cold blocks and repairs
    whatever is quarantined (``JobStats.scrub_s``).

    on_split_complete: streaming hook — called once per executed split, in
    completion order, as each result's barrier clears (NOT at job end),
    with ``(split_index, read_result, split_wall_s)``.  This is the split-
    granular completion signal the HailServer's streaming assembly and the
    ServerFrontend's per-query latency accounting are built on; exposed
    here so callers of the serial executor can consume results
    incrementally too.
    """
    import collections as _collections
    from repro.core import governor as gvn

    gvn.note_job_start(store)   # job boundary for the hysteresis counter
    with obs_trace.span("job_plan", track="job"):
        qplan = q.plan(store, query)
    if store.layout != "pax":
        splits = hadoop_splits(store, qplan)
    elif splitting == "hail":
        splits = hail_splits(store, qplan, cluster.map_slots)
    else:
        splits = hadoop_splits(store, qplan)

    fail_after = (int(len(splits) * fail_node_at)
                  if fail_node_at is not None else None)
    failed_node = None
    rescheduled = 0

    # --- adaptive offer budget: ceil(offer_rate * unindexed), capped -------
    adapt_rid, adapt_col, build_budget = None, None, 0
    blocks_demoted = 0
    demote_pending_s = 0.0    # job-start demotion wall, charged to split 0
    if (adaptive is not None and store.layout == "pax"
            and query.filter is not None):
        adapt_col = query.filter_col
        quantum = adaptive_quantum(store, adaptive)
        adapt_rid, claim_demoted, claim_wall = claim_adaptive_replica(
            store, adapt_col, quantum)
        blocks_demoted += claim_demoted
        demote_pending_s += claim_wall
        if adapt_rid is not None and len(store.unindexed_blocks(adapt_rid)):
            build_budget = quantum

    def read_split(sp: Split):
        if store.layout != "pax":
            return q.read_hadoop(store, query, list(sp.block_ids))
        if reader == "kernels" and query.filter is not None:
            return q.read_hail_kernels(store, query, qplan,
                                       list(sp.block_ids))
        return q.read_hail(store, query, qplan, list(sp.block_ids))

    # --- sharded scan: waves of up to n_dev splits per fused dispatch -----
    use_sharded = (mesh is not None and store.layout == "pax"
                   and query.filter is not None)
    scan_axes: tuple = ()
    n_dev = 1
    if use_sharded:
        from repro.dist import sharding as shd
        scan_axes = shd.scan_mesh_axes(mesh)
        n_dev = shd.scan_device_count(mesh, scan_axes)
        use_sharded = bool(scan_axes) and n_dev > 1

    # --- dispatch phase: queue every split's read without blocking --------
    # (jax dispatches asynchronously; the per-split reads pipeline instead
    # of running dispatch->barrier->dispatch->barrier as the seed did)
    dispatched: list[tuple] = []          # (ReadResult, dispatch timestamp)
    build_s: list[float] = []             # per split, aligned with dispatched
    demote_s: list[float] = []            # per split, aligned with dispatched
    blocks_indexed = 0
    full_scan_blocks = 0
    blocks_quarantined = 0
    corrupt_retries = 0
    retry_count: _collections.Counter = _collections.Counter()

    def note_retries(block_ids):
        """Charge one re-plan attempt to each block; a block that keeps
        failing (nodes dying AND replicas rotting faster than the retry
        budget) surfaces a typed error instead of looping forever."""
        for b in block_ids:
            retry_count[b] += 1
            if retry_count[b] > recovery.max_retries:
                raise UnrecoverableDataError(
                    f"block {b}: re-plan retry budget "
                    f"({recovery.max_retries}) exhausted")

    wave: list[tuple] = []                # (split, gathered inputs) buffer

    def flush_wave():
        """Dispatch the buffered wave as ONE shard_map'd fused read; the
        gathered inputs are snapshots, so commits/demotions/failover that
        landed since gathering cannot change these splits' row-sets."""
        if not wave:
            return
        out = q.read_hail_batch_sharded(store, [query],
                                        [g for _, g in wave],
                                        mesh, scan_axes)
        for res_list, _shared in out:
            dispatched.append((res_list[0], time.perf_counter()))
        wave.clear()

    t_start = time.perf_counter()
    i = 0
    pending = list(splits)
    while i < len(pending):
        if fail_after is not None and i == fail_after and failed_node is None:
            # kill the node that would serve the next split and re-plan
            # (wave-buffered splits already gathered their inputs — like
            # completed map tasks, their results stand)
            pending, qplan, failed_node, rescheduled = failover_replan(
                store, query, pending, i)
            if rescheduled:
                note_retries(b for s in pending[-rescheduled:]
                             for b in s.block_ids)
            if i >= len(pending):
                break
        sp = pending[i]
        i += 1
        try:
            if use_sharded:
                gathered = q.gather_shared_scan_inputs(
                    store, [query], qplan, list(sp.block_ids))
                res = None
            else:
                res = read_split(sp)
        except CorruptBlockError as e:
            # detection -> recovery: quarantine the corrupt copy at the
            # namenode, re-plan against the now-smaller replica set (plan
            # raises UnrecoverableDataError once a block has no healthy
            # copy left), and re-queue this split's blocks as per-block
            # retry splits — the same shape the node-failure path emits.
            store.quarantine_block(e.replica_id, e.block_id)
            blocks_quarantined += 1
            corrupt_retries += 1
            obs_trace.instant("corrupt_retry", track="job",
                              args={"replica": e.replica_id,
                                    "block": e.block_id})
            note_retries(sp.block_ids)
            qplan = q.plan(store, query)
            pending.extend(
                Split(node=int(qplan.nodes[b]), block_ids=(b,),
                      index_scan=bool(qplan.index_scan[b]))
                for b in sp.block_ids)
            continue
        if use_sharded:
            wave.append((sp, gathered))
        else:
            dispatched.append((res, time.perf_counter()))
        if not sp.index_scan:
            full_scan_blocks += len(sp.block_ids)
        # --- adaptive piggyback: this full-scan split already read these
        # blocks — sort + index an offered few and commit them for the
        # NEXT job (this split's own read was dispatched pre-commit) ------
        d_wall, demote_pending_s = demote_pending_s, 0.0
        b_wall = 0.0
        if build_budget > 0:
            built, demoted, b_wall, dd_wall = piggyback_build(
                store, sp, adapt_rid, adapt_col, build_budget)
            build_budget -= built
            blocks_indexed += built
            blocks_demoted += demoted
            d_wall += dd_wall
        build_s.append(b_wall)
        demote_s.append(d_wall)
        if use_sharded and len(wave) == n_dev:
            flush_wave()
    flush_wave()   # ragged final wave (padded to n_dev with dead splits)

    # --- completion phase: one pass of barriers over the queued results ---
    bytes_read = 0
    masks, cols, split_s = [], [], []
    for k, (res, t_disp) in enumerate(dispatched):
        jax.block_until_ready(res.mask)
        split_s.append(time.perf_counter() - t_disp)
        obs_trace.complete_wall("split", t_disp, split_s[-1], track="job",
                                args={"split": k})
        bytes_read += int(res.bytes_read)   # lazy scalar -> host, post-barrier
        masks.append(np.asarray(res.mask))
        cols.append({c: np.asarray(v) for c, v in res.cols.items()})
        if on_split_complete is not None:
            on_split_complete(k, res, split_s[-1])
    compute_s = time.perf_counter() - t_start

    n_tasks = len(pending)
    overhead = n_tasks * (cluster.hail_sched_overhead_s
                          if splitting == "hail" and store.layout == "pax"
                          else cluster.sched_overhead_s)
    if failed_node is not None:
        store.namenode.revive(failed_node)

    # job boundary: budgeted background scrub (verify cold blocks, repair
    # anything quarantined) — corruption is found before queries hit it
    scrub_s = 0.0
    if recovery.scrub and store.scrubber is not None:
        t_s = time.perf_counter()
        store.scrubber.tick()
        scrub_s = time.perf_counter() - t_s
        obs_trace.complete_wall("scrub_tick", t_s, scrub_s, track="job")

    # job boundary: replication-controller quantum — the heat this job just
    # wrote into the AccessLog moves replica COUNTS (add hot / retire cold)
    if store.layout == "pax" and store.replicator is not None:
        store.replicator.tick()

    mask = np.concatenate(masks, axis=0)
    out = {c: np.concatenate([d[c] for d in cols], axis=0)
           for c in cols[0]} if cols else {}
    results = {"n_rows": int(mask.sum()),
               "sample": {c: v.reshape(-1)[mask.reshape(-1)][:8]
                          for c, v in out.items()}}
    if reduce_fn is not None:
        results["reduce"] = reduce_fn(out, mask)

    # simulated end-to-end: scheduling overhead amortized over the cluster's
    # parallel task slots, measured map compute spread over the nodes (this
    # box executes serially what the cluster runs n_nodes-wide), and modeled
    # disk time for the bytes actually read (index scans read less).
    disk_s = bytes_read / (cluster.disk_bw * cluster.n_nodes)
    e2e = (overhead / (cluster.n_nodes * cluster.map_slots)
           + compute_s / cluster.n_nodes + disk_s)
    modeled = overhead / (cluster.n_nodes * cluster.map_slots) + disk_s
    stats = JobStats(n_tasks=n_tasks, map_compute_s=compute_s,
                     overhead_s=overhead, bytes_read=bytes_read,
                     end_to_end_s=e2e,
                     record_reader_s=compute_s / cluster.n_nodes + disk_s,
                     results=results, rescheduled_tasks=rescheduled,
                     split_s=split_s, blocks_indexed=blocks_indexed,
                     index_build_s=sum(build_s), build_s=build_s,
                     full_scan_blocks=full_scan_blocks, modeled_s=modeled,
                     blocks_demoted=blocks_demoted, rekey_s=sum(demote_s),
                     demote_s=demote_s,
                     blocks_quarantined=blocks_quarantined,
                     corrupt_retries=corrupt_retries, scrub_s=scrub_s)
    obs_trace.complete_wall("job", t_start, compute_s, track="job",
                            args={"tasks": n_tasks,
                                  "bytes_read": bytes_read,
                                  "blocks_indexed": blocks_indexed,
                                  "rescheduled": rescheduled})
    obs_metrics.observe_job(stats)
    return stats


# ---------------------------------------------------------------------------
# SPMD aggregation engine (shard_map): map -> all_to_all shuffle -> reduce
# ---------------------------------------------------------------------------


def spmd_aggregate(mesh, key_col: jax.Array, val_col: jax.Array,
                   mask: jax.Array, n_buckets: int, axis: str = "data"):
    """GROUP-BY-sum: (blocks, rows) keys/values/mask sharded on `axis` ->
    (n_buckets,) sums + counts.  n_buckets must divide by mesh[axis]."""
    from jax.sharding import PartitionSpec as P
    try:  # jax >= 0.6 re-exports shard_map at the top level
        from jax import shard_map
    except ImportError:  # pinned 0.4.x: experimental home
        from jax.experimental.shard_map import shard_map

    n_dev = mesh.shape[axis]
    if n_dev <= 0 or n_buckets % n_dev != 0:
        raise ValueError(
            f"spmd_aggregate: n_buckets={n_buckets} must be a positive "
            f"multiple of mesh axis {axis!r} size {n_dev} (each device "
            f"reduces n_buckets/n_dev buckets after the all_to_all shuffle)")
    per_dev = n_buckets // n_dev

    def local(keys, vals, msk):
        k = (keys % n_buckets).astype(jnp.int32).reshape(-1)
        v = jnp.where(msk.reshape(-1), vals.reshape(-1).astype(jnp.float32), 0.0)
        c = msk.reshape(-1).astype(jnp.float32)
        # local combine: per-bucket partial sums (the MR "combiner")
        sums = jax.ops.segment_sum(v, k, num_segments=n_buckets)
        cnts = jax.ops.segment_sum(c, k, num_segments=n_buckets)
        # shuffle: bucket b belongs to device b // per_dev; all_to_all sends
        # chunk j of every mapper's partials to reducer j
        sums = sums.reshape(n_dev, per_dev)
        cnts = cnts.reshape(n_dev, per_dev)
        sums = jax.lax.all_to_all(sums, axis, 0, 0)    # (n_dev, per_dev)
        cnts = jax.lax.all_to_all(cnts, axis, 0, 0)
        # reduce: sum partials from every mapper
        return sums.sum(0), cnts.sum(0)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=(P(axis), P(axis)))
    sums, cnts = fn(key_col, val_col, mask)
    return sums.reshape(-1), cnts.reshape(-1)
