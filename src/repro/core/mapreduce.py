"""MapReduce engines over the HAIL block store.

Two executors:

* ``run_job`` — split-driven executor (the Hadoop-pipeline analogue): one
  jit dispatch per split (HailSplitting batches many blocks per dispatch);
  per-task overheads accounted explicitly (measured dispatch + configurable
  simulated scheduler constant, the paper's multi-second Hadoop overhead).
  Node-failure injection re-schedules a failed node's splits onto surviving
  replicas, falling back to full scan when the lost replica held the only
  matching index (paper Fig 8).

* ``spmd_aggregate`` — shard_map engine for cluster-wide aggregations:
  map+combine per device over the block-sharded store, hash-bucket shuffle
  via all_to_all, segment-sum reduce.  Degenerates gracefully on 1 device;
  lowerable on the 512-device production mesh (see tests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as q
from repro.core.splitting import Split, hadoop_splits, hail_splits
from repro.core.store import BlockStore


@dataclasses.dataclass
class JobStats:
    n_tasks: int
    map_compute_s: float
    overhead_s: float          # dispatch + simulated scheduling
    bytes_read: int
    end_to_end_s: float        # compute + overhead (simulated cluster walltime)
    record_reader_s: float
    results: dict
    rescheduled_tasks: int = 0


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """Simulated-cluster constants (documented in EXPERIMENTS.md)."""
    sched_overhead_s: float = 3.0      # Hadoop per-task scheduling (paper §6.4)
    hail_sched_overhead_s: float = 3.0 # same scheduler; fewer tasks is the win
    disk_bw: float = 100e6             # B/s (paper's 100MB/s disk)
    n_nodes: int = 10
    map_slots: int = 4


def run_job(store: BlockStore, query: q.HailQuery, *,
            splitting: str = "hail", cluster: ClusterModel = ClusterModel(),
            reduce_fn: Optional[Callable] = None,
            fail_node_at: Optional[float] = None) -> JobStats:
    """Execute filter/project (+optional reduce) over all blocks."""
    qplan = q.plan(store, query)
    if store.layout != "pax":
        splits = hadoop_splits(store, qplan)
    elif splitting == "hail":
        splits = hail_splits(store, qplan, cluster.map_slots)
    else:
        splits = hadoop_splits(store, qplan)

    n_tasks = len(splits)
    fail_after = (int(len(splits) * fail_node_at)
                  if fail_node_at is not None else None)
    failed_node = None
    rescheduled = 0

    compute_s = 0.0
    bytes_read = 0
    masks, cols = [], []
    i = 0
    pending = list(splits)
    while i < len(pending):
        if fail_after is not None and i == fail_after and failed_node is None:
            # kill the node that would serve the next split; re-plan the
            # not-yet-executed splits it owned onto surviving replicas
            failed_node = pending[i].node
            store.namenode.kill_node(failed_node)
            qplan = q.plan(store, query)
            survivors = [s for s in pending[i:] if s.node != failed_node]
            lost_blocks = [b for s in pending[i:] if s.node == failed_node
                           for b in s.block_ids]
            retries = [Split(node=int(qplan.nodes[b]), block_ids=(b,),
                             index_scan=bool(qplan.index_scan[b]))
                       for b in lost_blocks]
            rescheduled = len(retries)
            pending = pending[:i] + survivors + retries
            if i >= len(pending):
                break
        sp = pending[i]
        i += 1
        t0 = time.perf_counter()
        if store.layout == "pax":
            res = q.read_hail(store, query, qplan, list(sp.block_ids))
        else:
            res = q.read_hadoop(store, query, list(sp.block_ids))
        jax.block_until_ready(res.mask)
        compute_s += time.perf_counter() - t0
        bytes_read += res.bytes_read
        masks.append(np.asarray(res.mask))
        cols.append({c: np.asarray(v) for c, v in res.cols.items()})

    n_tasks = len(pending)
    overhead = n_tasks * (cluster.hail_sched_overhead_s
                          if splitting == "hail" and store.layout == "pax"
                          else cluster.sched_overhead_s)
    if failed_node is not None:
        store.namenode.revive(failed_node)

    mask = np.concatenate(masks, axis=0)
    out = {c: np.concatenate([d[c] for d in cols], axis=0)
           for c in cols[0]} if cols else {}
    results = {"n_rows": int(mask.sum()),
               "sample": {c: v.reshape(-1)[mask.reshape(-1)][:8]
                          for c, v in out.items()}}
    if reduce_fn is not None:
        results["reduce"] = reduce_fn(out, mask)

    # simulated end-to-end: scheduling overhead amortized over the cluster's
    # parallel task slots, measured map compute spread over the nodes (this
    # box executes serially what the cluster runs n_nodes-wide), and modeled
    # disk time for the bytes actually read (index scans read less).
    disk_s = bytes_read / (cluster.disk_bw * cluster.n_nodes)
    e2e = (overhead / (cluster.n_nodes * cluster.map_slots)
           + compute_s / cluster.n_nodes + disk_s)
    return JobStats(n_tasks=n_tasks, map_compute_s=compute_s,
                    overhead_s=overhead, bytes_read=bytes_read,
                    end_to_end_s=e2e,
                    record_reader_s=compute_s / cluster.n_nodes + disk_s,
                    results=results, rescheduled_tasks=rescheduled)


# ---------------------------------------------------------------------------
# SPMD aggregation engine (shard_map): map -> all_to_all shuffle -> reduce
# ---------------------------------------------------------------------------


def spmd_aggregate(mesh, key_col: jax.Array, val_col: jax.Array,
                   mask: jax.Array, n_buckets: int, axis: str = "data"):
    """GROUP-BY-sum: (blocks, rows) keys/values/mask sharded on `axis` ->
    (n_buckets,) sums + counts.  n_buckets must divide by mesh[axis]."""
    from jax.sharding import PartitionSpec as P
    from jax import shard_map

    n_dev = mesh.shape[axis]
    assert n_buckets % n_dev == 0
    per_dev = n_buckets // n_dev

    def local(keys, vals, msk):
        k = (keys % n_buckets).astype(jnp.int32).reshape(-1)
        v = jnp.where(msk.reshape(-1), vals.reshape(-1).astype(jnp.float32), 0.0)
        c = msk.reshape(-1).astype(jnp.float32)
        # local combine: per-bucket partial sums (the MR "combiner")
        sums = jax.ops.segment_sum(v, k, num_segments=n_buckets)
        cnts = jax.ops.segment_sum(c, k, num_segments=n_buckets)
        # shuffle: bucket b belongs to device b // per_dev; all_to_all sends
        # chunk j of every mapper's partials to reducer j
        sums = sums.reshape(n_dev, per_dev)
        cnts = cnts.reshape(n_dev, per_dev)
        sums = jax.lax.all_to_all(sums, axis, 0, 0)    # (n_dev, per_dev)
        cnts = jax.lax.all_to_all(cnts, axis, 0, 0)
        # reduce: sum partials from every mapper
        return sums.sum(0), cnts.sum(0)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P(axis), P(axis)),
                   out_specs=(P(axis), P(axis)))
    sums, cnts = fn(key_col, val_col, mask)
    return sums.reshape(-1), cnts.reshape(-1)
