"""Replicated PAX block store + namenode metadata (paper §3.2-§3.3).

``BlockStore`` holds R physically different replicas of every logical block:
replica r is sorted by its own key with a sparse clustered index and its own
checksums (sort order differs => checksums differ, exactly as in the paper).
An implicit ``__rowid__`` column preserves logical row identity, so *any*
replica reconstructs the logical block (failover invariant).

``Namenode`` is the central directory: ``dir_block`` (blockID -> datanodes)
plus HAIL's addition ``dir_rep`` ((blockID, node) -> HAILBlockReplicaInfo)
used by the scheduler to route map tasks to matching indexes (§3.3, §4.3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schema import ROWID, Schema


@dataclasses.dataclass(frozen=True)
class ReplicaInfo:
    """HAILBlockReplicaInfo: what the namenode knows about one replica."""
    block_id: int
    node: int
    sort_key: Optional[str]        # clustered-index key (None = unindexed)
    partition_size: int
    n_rows: int
    layout: str                    # 'pax' | 'row_ascii'
    nbytes: int


class Namenode:
    """Central metadata service (Dir_block + Dir_rep + liveness)."""

    def __init__(self):
        self.dir_block: dict[int, list[int]] = {}
        self.dir_rep: dict[tuple[int, int], ReplicaInfo] = {}
        self.dead: set[int] = set()

    def register(self, info: ReplicaInfo):
        self.dir_block.setdefault(info.block_id, []).append(info.node)
        self.dir_rep[(info.block_id, info.node)] = info

    def locate(self, block_id: int) -> list[int]:
        return [n for n in self.dir_block[block_id] if n not in self.dead]

    def replicas(self, block_id: int) -> list[ReplicaInfo]:
        return [self.dir_rep[(block_id, n)] for n in self.locate(block_id)]

    def get_hosts_with_index(self, block_id: int, key: str) -> list[int]:
        """The paper's new BlockLocation.getHostsWithIndex()."""
        return [r.node for r in self.replicas(block_id) if r.sort_key == key]

    def kill_node(self, node: int):
        self.dead.add(node)

    def revive(self, node: int | None = None):
        if node is None:
            self.dead.clear()
        else:
            self.dead.discard(node)


@dataclasses.dataclass
class Replica:
    """One sort order of the whole dataset: per-column (n_blocks, rows)."""
    sort_key: Optional[str]
    cols: dict[str, jax.Array]
    mins: Optional[jax.Array]              # (n_blocks, n_partitions)
    checksums: dict[str, jax.Array]        # col -> (n_blocks, n_chunks) u32
    nodes: np.ndarray                      # (n_blocks,) datanode per block

    @property
    def nbytes(self) -> int:
        return int(sum(v.size * v.dtype.itemsize for v in self.cols.values()))


@dataclasses.dataclass
class BlockStore:
    schema: Schema
    n_blocks: int
    rows_per_block: int
    partition_size: int
    replicas: list[Replica]
    bad_counts: jax.Array                  # (n_blocks,) bad records per block
    namenode: Namenode
    layout: str = "pax"
    bad_original: Optional[jax.Array] = None  # (n_blocks, rows) upload order

    @property
    def replication(self) -> int:
        return len(self.replicas)

    def replica_by_key(self, key: str) -> Optional[int]:
        for i, r in enumerate(self.replicas):
            if r.sort_key == key:
                return i
        return None

    def alive_replica_ids(self, block_id: int) -> list[int]:
        """Replica indices whose datanode for this block is alive."""
        out = []
        for i, r in enumerate(self.replicas):
            if int(r.nodes[block_id]) not in self.namenode.dead:
                out.append(i)
        return out

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self.replicas)


def assign_nodes(n_blocks: int, replication: int, n_nodes: int) -> np.ndarray:
    """(replication, n_blocks) datanode placement: replicas of a block land
    on distinct nodes (HDFS invariant), blocks round-robin."""
    assert replication <= n_nodes, "replication must be <= cluster size"
    out = np.zeros((replication, n_blocks), dtype=np.int64)
    for b in range(n_blocks):
        base = b % n_nodes
        for r in range(replication):
            out[r, b] = (base + r) % n_nodes
    return out
