"""Replicated PAX block store + namenode metadata (paper §3.2-§3.3).

``BlockStore`` holds R physically different replicas of every logical block:
replica r is sorted by its own key with a sparse clustered index and its own
checksums (sort order differs => checksums differ, exactly as in the paper).
An implicit ``__rowid__`` column preserves logical row identity, so *any*
replica reconstructs the logical block (failover invariant).

``Namenode`` is the central directory: ``dir_block`` (blockID -> datanodes)
plus HAIL's addition ``dir_rep`` ((blockID, node) -> HAILBlockReplicaInfo)
used by the scheduler to route map tasks to matching indexes (§3.3, §4.3).

Adaptive indexing (LIAH, the paper's sequel) makes the store STATE-EVOLVING:
blocks may upload unindexed (``Replica.indexed`` all-False) and running jobs
commit per-block clustered indexes back via ``commit_block_indexes`` — the
replica's columns, root directory, checksums, per-block index flags and the
namenode's Dir_rep all advance together, and query-side caches (the bad-row
mask, any attached ``core/cache.BlockCache``) are invalidated.  Planning reads this LIVE state, so repeated jobs
converge from all-full-scan to all-index-scan.

The index governor (core/governor.py) adds the REVERSE transition:
``demote_replica`` drops a replica's per-block indexes back to
``sort_key=None`` upload order — columns are un-sorted via the logical
``__rowid__`` column, the root directory zeroes, checksums are recomputed,
Dir_rep rewinds, the bad-mask cache invalidates — so a shifted workload can
re-claim and re-key the replica through the same claim/commit path.  When a
governor is attached (``store.governor``), ``commit_block_indexes`` also
enforces its storage budget as a hard backstop: commits are trimmed so the
total indexed-block count can never exceed the budget.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checksum as ck
from repro.core import index as idx
from repro.core.schema import ROWID, Schema


@dataclasses.dataclass(frozen=True)
class ReplicaInfo:
    """HAILBlockReplicaInfo: what the namenode knows about one replica."""
    block_id: int
    node: int
    sort_key: Optional[str]        # clustered-index key (None = unindexed)
    partition_size: int
    n_rows: int
    layout: str                    # 'pax' | 'row_ascii'
    nbytes: int


class Namenode:
    """Central metadata service (Dir_block + Dir_rep + liveness)."""

    def __init__(self):
        self.dir_block: dict[int, list[int]] = {}
        self.dir_rep: dict[tuple[int, int], ReplicaInfo] = {}
        self.dead: set[int] = set()
        # (block_id, node) pairs whose replica failed read-path checksum
        # verification — excluded from placement like a dead node, but at
        # BLOCK granularity, and reversible only by repair_blocks (never by
        # revive: a revived node's corrupt block is still corrupt)
        self.quarantined: set[tuple[int, int]] = set()

    def register(self, info: ReplicaInfo):
        self.dir_block.setdefault(info.block_id, []).append(info.node)
        self.dir_rep[(info.block_id, info.node)] = info

    def locate(self, block_id: int) -> list[int]:
        return [n for n in self.dir_block[block_id]
                if n not in self.dead
                and (block_id, n) not in self.quarantined]

    def quarantine(self, block_id: int, node: int):
        self.quarantined.add((block_id, node))

    def clear_quarantine(self, block_id: int, node: int):
        self.quarantined.discard((block_id, node))

    def is_quarantined(self, block_id: int, node: int) -> bool:
        return (block_id, node) in self.quarantined

    def replicas(self, block_id: int) -> list[ReplicaInfo]:
        return [self.dir_rep[(block_id, n)] for n in self.locate(block_id)]

    def get_hosts_with_index(self, block_id: int, key: str) -> list[int]:
        """The paper's new BlockLocation.getHostsWithIndex()."""
        return [r.node for r in self.replicas(block_id) if r.sort_key == key]

    def update_index(self, block_id: int, node: int,
                     sort_key: Optional[str]):
        """Adaptive-index commit (or governor demotion rewind): a running
        job built — or the governor dropped — a clustered index for this
        replica; advance/rewind Dir_rep so later planning sees it.
        ``sort_key=None`` rewinds the replica to unindexed."""
        info = self.dir_rep[(block_id, node)]
        self.dir_rep[(block_id, node)] = dataclasses.replace(
            info, sort_key=sort_key)

    def unregister(self, block_id: int, node: int):
        """Decommission: drop one replica's (block, node) registration —
        Dir_block, Dir_rep and any quarantine record for the pair."""
        nodes = self.dir_block.get(block_id, [])
        if node in nodes:
            nodes.remove(node)
        self.dir_rep.pop((block_id, node), None)
        self.quarantined.discard((block_id, node))

    def kill_node(self, node: int):
        self.dead.add(node)

    def revive(self, node: int | None = None):
        if node is None:
            self.dead.clear()
        else:
            self.dead.discard(node)


@dataclasses.dataclass
class Replica:
    """One sort order of the whole dataset: per-column (n_blocks, rows).

    ``sort_key`` is the replica's clustered-index key; ``indexed`` tracks the
    PER-BLOCK index state (adaptive uploads ship blocks unindexed and jobs
    commit indexes block by block).  An unindexed block's rows sit in upload
    order; an indexed block's rows are sorted by ``sort_key`` with bad
    records at the tail.  ``sort_key is None`` with all-False ``indexed``
    means the replica is still unclaimed — the first adaptive commit claims
    it for the workload's filter column.
    """
    sort_key: Optional[str]
    cols: dict[str, jax.Array]
    mins: Optional[jax.Array]              # (n_blocks, n_partitions)
    checksums: dict[str, jax.Array]        # col -> (n_blocks, n_chunks) u32
    nodes: np.ndarray                      # (n_blocks,) datanode per block
    indexed: Optional[np.ndarray] = None   # (n_blocks,) bool per-block state
    retired: bool = False                  # decommissioned TOMBSTONE: the
    #   slot stays (replica ids are baked into caches, the AccessLog and
    #   recorded plans) but planning, repair, scrubbing and byte accounting
    #   all skip it; its columns are dropped

    def __post_init__(self):
        if self.indexed is None:
            self.indexed = np.full(len(self.nodes),
                                   self.sort_key is not None, dtype=bool)

    def block_indexed(self, block_id: int) -> bool:
        return self.sort_key is not None and bool(self.indexed[block_id])

    @property
    def nbytes(self) -> int:
        return int(sum(v.size * v.dtype.itemsize for v in self.cols.values()))


@dataclasses.dataclass
class RepairStats:
    """What one ``repair_blocks`` pass did (feeds the repair-cost model:
    modeled repair I/O = bytes_rewritten read from the donor + written to
    the victim, over the cluster disk bandwidth)."""
    blocks_repaired: int = 0
    unrepairable: int = 0
    bytes_rewritten: int = 0
    wall_s: float = 0.0


@dataclasses.dataclass
class BlockStore:
    schema: Schema
    n_blocks: int
    rows_per_block: int
    partition_size: int
    replicas: list[Replica]
    bad_counts: jax.Array                  # (n_blocks,) bad records per block
    namenode: Namenode
    layout: str = "pax"
    bad_original: Optional[jax.Array] = None  # (n_blocks, rows) upload order
    access_log: Any = None                 # governor.AccessLog (lazy, set by
    #   the record readers' note_read attribution — persistent across jobs)
    governor: Any = None                   # governor.IndexGovernor when the
    #   store is budget-governed (commit_block_indexes enforces its budget)
    block_cache: Any = None                # cache.BlockCache when a serving
    #   layer caches decoded split inputs — commit_block_indexes and
    #   demote_replica invalidate the touched replica's entries
    verify_reads: bool = True              # read-path checksum verification
    #   (amortized to BlockCache fills when a cache is attached)
    scrubber: Any = None                   # runtime.scrubber.Scrubber when
    #   background verification is attached (ticks at job/flush boundaries)
    result_cache: Any = None               # cache.ResultCache when a serving
    #   layer caches materialized answers — dropped wholesale by every
    #   destructive transition (and keyed by ``version`` as a backstop)
    replicator: Any = None                 # governor.ReplicationController
    #   when heat-driven dynamic replication is attached (ticks at
    #   job/flush boundaries like the scrubber)
    version: int = 0                       # bumped by every destructive
    #   transition; part of the result-cache key, so answers filled against
    #   an older store state are structurally unreachable

    def _note_destructive(self):
        """Every state transition that changes what a query would read
        (index commit, demotion, quarantine, repair) funnels through here:
        bump the store version and drop all materialized answers."""
        self.version += 1
        if self.result_cache is not None:
            self.result_cache.invalidate_store()

    @property
    def replication(self) -> int:
        return len(self.replicas)

    def live_replica_ids(self) -> list[int]:
        """Replica slots that are not decommissioned tombstones."""
        return [i for i, r in enumerate(self.replicas) if not r.retired]

    def template_replica(self) -> Replica:
        """A live replica to read schema/dtype metadata from (replica 0
        may be a retired tombstone with its columns dropped)."""
        for r in self.replicas:
            if not r.retired:
                return r
        raise ValueError("store has no live replicas")

    def replica_for(self, key: str) -> Optional[int]:
        """Replica to READ a ``key`` index from: when several replicas share
        a sort_key (possible after demote→re-claim leaves one mid-re-key),
        prefer the one with the highest ``indexed`` fraction — it qualifies
        the most blocks for index scan; ties go to the lowest id."""
        best, best_frac = None, -1.0
        for i, r in enumerate(self.replicas):
            if not r.retired and r.sort_key == key:
                frac = float(r.indexed.mean()) if len(r.indexed) else 0.0
                if frac > best_frac:
                    best, best_frac = i, frac
        return best

    def replica_by_key(self, key: str) -> Optional[int]:
        return self.replica_for(key)

    def alive_replica_ids(self, block_id: int) -> list[int]:
        """Replica indices whose datanode for this block is alive AND whose
        copy of the block is not quarantined — the set ``plan()`` may place
        reads on."""
        out = []
        for i, r in enumerate(self.replicas):
            if r.retired:
                continue
            node = int(r.nodes[block_id])
            if (node not in self.namenode.dead
                    and not self.namenode.is_quarantined(block_id, node)):
                out.append(i)
        return out

    # -- corruption: quarantine / verification / repair ---------------------

    def quarantine_block(self, replica_id: int, block_id: int):
        """Record that this replica's copy of a block failed verification.
        The (block, node) pair leaves ``locate``/``alive_replica_ids`` (and
        hence ``plan``) until ``repair_blocks`` restores it; any cached
        gathers touching it are dropped."""
        node = int(self.replicas[replica_id].nodes[block_id])
        self.namenode.quarantine(block_id, node)
        if self.block_cache is not None:
            self.block_cache.invalidate_blocks(replica_id, [block_id])
        self._note_destructive()
        from repro.kernels import ops
        ops.DISPATCH_COUNTS["blocks_quarantined"] += 1
        from repro.obs import trace as obs_trace
        obs_trace.instant("quarantine", track="store",
                          args={"replica": replica_id, "block": block_id,
                                "node": node})

    def is_quarantined(self, replica_id: int, block_id: int) -> bool:
        return self.namenode.is_quarantined(
            block_id, int(self.replicas[replica_id].nodes[block_id]))

    def quarantined_blocks(self, replica_id: int) -> list[int]:
        nodes = self.replicas[replica_id].nodes
        return [b for b in range(self.n_blocks)
                if (b, int(nodes[b])) in self.namenode.quarantined]

    def verify_block(self, replica_id: int, block_id: int) -> bool:
        """Full integrity check of one (replica, block): every column's
        chunk checksums, plus root-directory consistency (mins re-derived
        from the verified key column) when the block is indexed.  Used by
        the scrubber and by repair-source selection."""
        from repro.kernels import ops
        rep = self.replicas[replica_id]
        names = sorted(rep.cols)
        sl = slice(block_id, block_id + 1)
        data = jnp.stack([rep.cols[c][sl] for c in names])
        sums = jnp.stack([rep.checksums[c][sl] for c in names])
        if not bool(np.asarray(ops.verify_blocks(data, sums)).all()):
            return False
        if rep.block_indexed(block_id):
            return bool(np.asarray(ops.verify_root(
                rep.mins[sl], rep.cols[rep.sort_key][sl],
                partition_size=self.partition_size)).all())
        return True

    def _healthy_source(self, victim_id: int, block_id: int) -> Optional[int]:
        """A replica that can donate this block: alive, unquarantined, and
        freshly verified (a donor with latent corruption must not launder
        its rot into the repair)."""
        for rid in self.alive_replica_ids(block_id):
            if rid != victim_id and self.verify_block(rid, block_id):
                return rid
        return None

    def repair_blocks(self) -> "RepairStats":
        """Rebuild every quarantined block of this store from a healthy
        replica — the HAIL twist being that repair PRESERVES the victim's
        clustered index instead of byte-copying the donor's (differently
        sorted) bytes:

        1. donor rows return to upload order by sorting on the logical
           ``__rowid__`` column (any replica reconstructs the logical
           block — the same invariant failover relies on);
        2. if the victim block was indexed, re-sort under the VICTIM's own
           ``sort_key`` with bad records to the tail (the stable device
           sort reproduces a fresh eager upload's layout bit-for-bit) and
           rebuild the root-directory row;
        3. splice columns + root + freshly recomputed checksums, clear the
           quarantine, and invalidate the bad-mask/block caches for just
           the touched blocks.

        The governor's AccessLog is untouched — repair restores bytes, it
        is not a workload event.  Blocks with no healthy donor stay
        quarantined and are counted ``unrepairable``.
        """
        import time as _time
        from repro.kernels import ops
        assert self.layout == "pax", "repair targets PAX replicas"
        t0 = _time.perf_counter()
        stats = RepairStats()
        by_rep: dict[int, list[int]] = {}
        node_rep = {(b, int(r.nodes[b])): i
                    for i, r in enumerate(self.replicas) if not r.retired
                    for b in range(self.n_blocks)}
        for (b, node) in sorted(self.namenode.quarantined):
            rid = node_rep.get((b, node))
            if rid is not None:
                by_rep.setdefault(rid, []).append(b)
        big = jnp.iinfo(jnp.int32).max
        for rid, blocks in sorted(by_rep.items()):
            rep = self.replicas[rid]
            repaired = []
            for b in blocks:
                src_id = self._healthy_source(rid, b)
                if src_id is None:
                    stats.unrepairable += 1
                    continue
                src = self.replicas[src_id]
                # donor -> upload order via logical row identity
                _, upload_cols, _ = ops.sort_block(
                    src.cols[ROWID][b][None],
                    {c: v[b][None] for c, v in src.cols.items()})
                if rep.block_indexed(b):
                    keys = jnp.where(self.bad_original[b][None], big,
                                     upload_cols[rep.sort_key])
                    _, new_cols, _ = ops.sort_block(keys, upload_cols)
                    rep.mins = rep.mins.at[b].set(idx.build_block_roots(
                        new_cols[rep.sort_key], self.partition_size)[0])
                else:
                    new_cols = upload_cols
                    rep.mins = rep.mins.at[b].set(jnp.int32(0))
                for c, v in new_cols.items():
                    rep.cols[c] = rep.cols[c].at[b].set(v[0])
                    rep.checksums[c] = rep.checksums[c].at[b].set(
                        ck.chunk_checksums(v[0]))
                    stats.bytes_rewritten += int(
                        v[0].size * v[0].dtype.itemsize)
                self.namenode.clear_quarantine(b, int(rep.nodes[b]))
                repaired.append(b)
                stats.blocks_repaired += 1
                ops.DISPATCH_COUNTS["blocks_repaired"] += 1
            if repaired:
                self.__dict__.get("_bad_mask_cache", {}).pop(rid, None)
                if self.block_cache is not None:
                    self.block_cache.invalidate_blocks(rid, repaired)
        if stats.blocks_repaired:
            self._note_destructive()
        stats.wall_s = _time.perf_counter() - t0
        from repro.obs import trace as obs_trace
        obs_trace.complete_wall("repair_blocks", t0, stats.wall_s,
                                track="store",
                                args={"repaired": stats.blocks_repaired,
                                      "unrepairable": stats.unrepairable,
                                      "bytes": stats.bytes_rewritten})
        return stats

    @property
    def nbytes(self) -> int:
        return sum(r.nbytes for r in self.replicas)

    # -- adaptive indexing: the store is state-evolving ---------------------

    def adaptive_replica_for(self, key: str) -> Optional[int]:
        """Replica to (keep) converging toward a ``key`` index: a replica
        already keyed on ``key`` if one exists, else the first unclaimed
        (sort_key None) PAX replica.  None when every replica is claimed by
        some other key — adaptive indexing for ``key`` is then impossible."""
        rid = self.replica_by_key(key)
        if rid is not None:
            return rid
        if self.layout != "pax":
            return None
        for i, r in enumerate(self.replicas):
            if not r.retired and r.sort_key is None:
                return i
        return None

    def unindexed_blocks(self, replica_id: int) -> np.ndarray:
        return np.nonzero(~self.replicas[replica_id].indexed)[0]

    def indexed_fraction(self, key: str) -> float:
        """Fraction of blocks index-scannable for ``key`` (convergence)."""
        rid = self.replica_for(key)
        if rid is None:
            return 0.0
        return float(self.replicas[rid].indexed.mean())

    def total_indexed_blocks(self) -> int:
        """Per-block indexes held across ALL replicas — the quantity the
        governor's storage budget bounds."""
        return int(sum(int(r.indexed.sum()) for r in self.replicas
                       if r.sort_key is not None))

    def commit_block_indexes(self, replica_id: int, block_ids,
                             sort_key: str, sorted_cols: dict,
                             new_mins: jax.Array, new_checksums: dict) -> int:
        """Commit freshly built per-block clustered indexes (adaptive path).

        Splices the sorted columns, per-block root directories and recomputed
        checksums into the replica (functional ``.at`` updates — reads already
        dispatched against the old arrays are unaffected), flips the blocks'
        ``indexed`` flags, advances the namenode's Dir_rep, and invalidates
        the per-replica bad-row-mask cache (tail layout changed).

        When a governor is attached, the commit is trimmed to the budget's
        remaining room (hard backstop — run_job normally demotes/trims
        BEFORE building, so a trim here means someone committed directly).
        Returns the number of blocks actually committed.
        """
        rep = self.replicas[replica_id]
        assert rep.sort_key in (None, sort_key), \
            f"replica {replica_id} already keyed on {rep.sort_key!r}"
        bsel = np.asarray(block_ids)
        # never commit a quarantined block: its source bytes are suspect and
        # a commit would recompute "valid" checksums over corrupt data,
        # laundering the corruption past every future verification
        clean = np.array([not self.is_quarantined(replica_id, int(b))
                          for b in bsel], dtype=bool)
        if not clean.all():
            bsel = bsel[clean]
            sorted_cols = {c: v[clean] for c, v in sorted_cols.items()}
            new_mins = new_mins[clean]
            new_checksums = {c: s[clean] for c, s in new_checksums.items()}
        if self.governor is not None:
            keep = self.governor.admit(self, replica_id, len(bsel))
            if keep < len(bsel):
                bsel = bsel[:keep]
                sorted_cols = {c: v[:keep] for c, v in sorted_cols.items()}
                new_mins = new_mins[:keep]
                new_checksums = {c: s[:keep]
                                 for c, s in new_checksums.items()}
        if len(bsel) == 0:
            return 0                     # nothing fits: do not even claim
        rep.sort_key = sort_key
        for c, v in sorted_cols.items():
            rep.cols[c] = rep.cols[c].at[bsel].set(v)
        rep.mins = idx.merge_block_roots(rep.mins, bsel, new_mins)
        for c, s in new_checksums.items():
            rep.checksums[c] = rep.checksums[c].at[bsel].set(s)
        rep.indexed[bsel] = True
        for b in bsel:
            self.namenode.update_index(int(b), int(rep.nodes[b]), sort_key)
        self.__dict__.get("_bad_mask_cache", {}).pop(replica_id, None)
        if self.block_cache is not None:
            self.block_cache.invalidate_replica(replica_id)
        self._note_destructive()
        from repro.core import governor as gv
        gv.note_commit(self, replica_id, sort_key)
        return len(bsel)

    def demote_replica(self, replica_id: int) -> int:
        """Governor eviction: drop a replica's clustered index entirely —
        the store's first DESTRUCTIVE state transition.

        The replica's rows return to upload order by sorting on the logical
        ``__rowid__`` column (identity for blocks that were never indexed),
        the root directory zeroes, per-replica checksums are recomputed for
        the restored byte order, ``sort_key``/``indexed`` rewind to the
        unclaimed state, the namenode's Dir_rep rewinds per block, and the
        bad-row-mask cache invalidates (bad rows move from the sorted tail
        back to their original upload positions).  The replica is then
        re-claimable by a later workload via ``adaptive_replica_for`` +
        ``commit_block_indexes``.  Returns the number of per-block indexes
        dropped (budget blocks freed).
        """
        assert self.layout == "pax", "only PAX replicas carry indexes"
        rep = self.replicas[replica_id]
        assert rep.sort_key is not None, \
            f"replica {replica_id} is already unindexed"
        old_key = rep.sort_key
        bsel = np.nonzero(rep.indexed)[0]       # only indexed blocks moved;
        dropped = len(bsel)                     # the rest are already in
        # quarantined blocks are NOT un-sorted or re-checksummed: their
        # bytes are corrupt, and recomputing checksums over them would
        # launder the corruption into a "verified" state.  They keep their
        # quarantine through the demotion (the budget still counts their
        # index as dropped) and are restored to upload order by
        # repair_blocks, which sees block_indexed()==False post-demote.
        qset = {int(b) for b in self.quarantined_blocks(replica_id)}
        if qset:
            bsel = np.array([b for b in bsel if int(b) not in qset],
                            dtype=np.int64)
        if len(bsel):                           # upload order (mid-re-key)
            # device-side un-sort: sorting by the logical __rowid__ column
            # IS the inverse permutation back to upload order, and it runs
            # through the same kernels/block_sort bitonic network the build
            # path uses — so the rekey_s wall charged to demotions is honest
            # on TPU, not a host argsort artifact (ROADMAP item).
            from repro.kernels import ops
            _, unsorted, _ = ops.sort_block(
                rep.cols[ROWID][bsel],
                {c: v[bsel] for c, v in rep.cols.items()})
            rep.cols = {c: v.at[bsel].set(unsorted[c])
                        for c, v in rep.cols.items()}
            rep.checksums = {
                c: s.at[bsel].set(jax.vmap(ck.chunk_checksums)(
                    rep.cols[c][bsel]))
                for c, s in rep.checksums.items()}
        rep.mins = jnp.zeros(
            (self.n_blocks, self.rows_per_block // self.partition_size),
            jnp.int32)
        rep.sort_key = None
        rep.indexed = np.zeros(self.n_blocks, dtype=bool)
        for b in range(self.n_blocks):
            self.namenode.update_index(b, int(rep.nodes[b]), None)
        self.__dict__.get("_bad_mask_cache", {}).pop(replica_id, None)
        if self.block_cache is not None:
            self.block_cache.invalidate_replica(replica_id)
        self._note_destructive()
        if self.access_log is not None:
            self.access_log.forget_replica(replica_id)
        if self.governor is not None:
            self.governor.note_demotion(replica_id, old_key, dropped)
        return dropped

    # -- dynamic replication: replica COUNT follows measured heat -----------

    def add_replica(self, n_nodes: Optional[int] = None) -> int:
        """Scale-UP arm of dynamic replication: clone the dataset into a
        fresh, UNCLAIMED replica in upload order — claimable by the next
        adaptive job for whatever column is hot (the HAIL win: every
        replica carries its own clustered index, so adding a replica adds
        an index *slot*, not just read bandwidth).

        Per block, the first healthy (alive, unquarantined) replica
        donates; donor rows return to upload order by sorting on the
        logical ``__rowid__`` column (the same device-side un-sort repair
        and demotion use — identity for unindexed donors), and checksums
        are recomputed for the restored byte order.  Placement stays
        consistent with ``assign_nodes``: block b lands on
        ``(b + slot) % n_nodes`` for the lowest node-offset ``slot`` no
        live replica occupies, preserving the distinct-nodes invariant.

        Appending is NON-destructive — planning prefers the lowest alive
        id for full scans and the new replica is unindexed, so no existing
        plan, cached gather or materialized answer changes meaning; the
        store version is untouched.  Returns the new replica id.
        """
        from repro.kernels import ops
        assert self.layout == "pax", "dynamic replication targets PAX stores"
        live = self.live_replica_ids()
        if n_nodes is None:
            n_nodes = max(int(self.replicas[i].nodes.max())
                          for i in live) + 1
        taken = {int(self.replicas[i].nodes[0]) % n_nodes for i in live}
        free = [s for s in range(n_nodes) if s not in taken]
        if not free:
            raise ValueError(
                f"cannot add replica: all {n_nodes} node offsets hold a "
                f"live replica (replication would exceed cluster size)")
        slot = free[0]
        donor = np.empty(self.n_blocks, dtype=np.int64)
        for b in range(self.n_blocks):
            alive = self.alive_replica_ids(b)
            if not alive:
                raise ValueError(
                    f"cannot add replica: block {b} has no healthy copy "
                    f"to clone from")
            donor[b] = alive[0]
        tmpl = self.template_replica()
        rows = self.rows_per_block
        new_cols = {c: jnp.zeros((self.n_blocks, rows), v.dtype)
                    for c, v in tmpl.cols.items()}
        for rid in np.unique(donor):
            bsel = np.nonzero(donor == rid)[0]
            src = self.replicas[int(rid)]
            # donor -> upload order via logical row identity (one batched
            # device sort per donor replica, not one per block)
            _, up, _ = ops.sort_block(
                src.cols[ROWID][bsel],
                {c: v[bsel] for c, v in src.cols.items()})
            new_cols = {c: new_cols[c].at[bsel].set(up[c])
                        for c in new_cols}
        new_sums = {c: jax.vmap(ck.chunk_checksums)(v)
                    for c, v in new_cols.items()}
        nodes = np.array([(b % n_nodes + slot) % n_nodes
                          for b in range(self.n_blocks)], dtype=np.int64)
        rep = Replica(sort_key=None, cols=new_cols,
                      mins=jnp.zeros(
                          (self.n_blocks, rows // self.partition_size),
                          jnp.int32),
                      checksums=new_sums, nodes=nodes)
        self.replicas.append(rep)
        rid = len(self.replicas) - 1
        per_block_bytes = rep.nbytes // self.n_blocks
        for b in range(self.n_blocks):
            self.namenode.register(ReplicaInfo(
                block_id=b, node=int(nodes[b]), sort_key=None,
                partition_size=self.partition_size, n_rows=rows,
                layout="pax", nbytes=per_block_bytes))
        ops.DISPATCH_COUNTS["replicas_added"] += 1
        from repro.obs import trace as obs_trace
        obs_trace.instant("add_replica", track="store",
                          args={"replica": rid, "node_offset": slot})
        return rid

    def decommission_replica(self, replica_id: int) -> int:
        """Scale-DOWN arm of dynamic replication: retire a cold replica —
        a DESTRUCTIVE transition like demotion, but terminal.

        The replica becomes a tombstone: its slot stays (replica ids are
        baked into caches, the AccessLog and recorded plans — removal
        would silently re-key every later replica) but ``retired`` drops
        it from planning, repair, scrubbing and byte accounting, its
        columns/checksums are freed, and the namenode unregisters every
        (block, node) pair — including quarantined ones, so a replica
        rotting in quarantine can still be decommissioned.  Bumps
        ``store.version`` and invalidates both cache tiers.

        Refuses (typed ``ValueError``) when any block would lose its last
        healthy copy.  Returns the number of per-block indexes dropped.
        """
        assert self.layout == "pax", "dynamic replication targets PAX stores"
        rep = self.replicas[replica_id]
        if rep.retired:
            raise ValueError(f"replica {replica_id} is already retired")
        for b in range(self.n_blocks):
            others = [i for i in self.alive_replica_ids(b)
                      if i != replica_id]
            if not others:
                raise ValueError(
                    f"cannot decommission replica {replica_id}: block {b} "
                    f"would lose its last healthy copy")
        dropped = (int(rep.indexed.sum())
                   if rep.sort_key is not None else 0)
        for b in range(self.n_blocks):
            self.namenode.unregister(b, int(rep.nodes[b]))
        rep.retired = True
        rep.sort_key = None
        rep.indexed = np.zeros(self.n_blocks, dtype=bool)
        rep.cols = {}
        rep.checksums = {}
        rep.mins = None
        self.__dict__.get("_bad_mask_cache", {}).pop(replica_id, None)
        if self.block_cache is not None:
            self.block_cache.invalidate_replica(replica_id)
        self._note_destructive()
        if self.access_log is not None:
            self.access_log.forget_replica(replica_id)
        from repro.kernels import ops
        ops.DISPATCH_COUNTS["replicas_decommissioned"] += 1
        from repro.obs import trace as obs_trace
        obs_trace.instant("decommission_replica", track="store",
                          args={"replica": replica_id,
                                "indexes_dropped": dropped})
        return dropped


def assign_nodes(n_blocks: int, replication: int, n_nodes: int) -> np.ndarray:
    """(replication, n_blocks) datanode placement: replicas of a block land
    on distinct nodes (HDFS invariant), blocks round-robin."""
    if replication > n_nodes:
        raise ValueError(
            f"replication={replication} exceeds cluster size "
            f"n_nodes={n_nodes}: replicas of a block must land on "
            f"distinct nodes")
    out = np.zeros((replication, n_blocks), dtype=np.int64)
    for b in range(n_blocks):
        base = b % n_nodes
        for r in range(replication):
            out[r, b] = (base + r) % n_nodes
    return out
