"""Chunk checksums (HDFS keeps a CRC per 512B chunk; HAIL recomputes them
per replica because each replica's sort order differs — §3.2).

We use a vectorized position-weighted Fletcher-style sum: order-sensitive
(detects permutation, not just corruption), cheap on accelerator, u32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

CHUNK = 512  # bytes, HDFS default
_P = jnp.uint32(65521)


def _to_chunks(data: jax.Array) -> jax.Array:
    """Flatten any numeric array to padded uint8 chunks (n_chunks, CHUNK)."""
    raw = jax.lax.bitcast_convert_type(data.reshape(-1), jnp.uint8).reshape(-1)
    pad = (-raw.size) % CHUNK
    raw = jnp.pad(raw, (0, pad))
    return raw.reshape(-1, CHUNK)


def chunk_checksums(data: jax.Array) -> jax.Array:
    """-> uint32 (n_chunks,) position-weighted checksums."""
    chunks = _to_chunks(data).astype(jnp.uint32)
    weights = (jnp.arange(CHUNK, dtype=jnp.uint32) % _P) + 1
    s1 = chunks.sum(axis=1) % _P
    s2 = (chunks * weights).sum(axis=1) % _P
    return (s2 << 16) | s1


def verify(data: jax.Array, sums: jax.Array) -> jax.Array:
    """-> bool (n_chunks,) chunk validity."""
    return chunk_checksums(data) == sums


def block_checksums(cols: dict[str, jax.Array]) -> dict[str, jax.Array]:
    return {k: chunk_checksums(v) for k, v in sorted(cols.items())}


def verify_block(cols: dict[str, jax.Array], sums: dict[str, jax.Array]) -> jax.Array:
    ok = jnp.asarray(True)
    for k in sorted(cols):
        ok &= verify(cols[k], sums[k]).all()
    return ok


def verify_blocks(data: jax.Array, sums: jax.Array) -> jax.Array:
    """Batched read-path verify: data (C, B, rows), sums (C, B, chunks)
    -> bool (C, B), True where EVERY chunk of (col, block) matches.
    All uservisits columns are int32, so a multi-column stack is free."""
    per = jax.vmap(jax.vmap(lambda d, s: (chunk_checksums(d) == s).all()))
    return per(data, sums)


def verify_root(mins: jax.Array, sorted_keys: jax.Array,
                partition_size: int) -> jax.Array:
    """Root-directory consistency: mins (B, P) vs sorted key column
    (B, rows) -> bool (B,).  The root directory is NOT checksummed (it is
    derived state), so a corrupt/stale directory is caught by re-deriving
    the partition minima from the (checksum-verified) key column."""
    return (mins == sorted_keys[:, ::partition_size]).all(axis=1)
