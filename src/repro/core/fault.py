"""Seeded fault injection + the typed corruption-error vocabulary.

HAIL recomputes per-replica checksums precisely because HDFS's corruption
story must survive physically different replicas (paper §3.2) — each
replica's sort order differs, so each carries its own chunk checksums.
This module is the adversary that proves the read path actually *uses*
them: a deterministic ``FaultInjector`` flips bits in PAX columns,
scrambles root directories, and truncates checksum arrays of chosen
(replica, block)s, so tests and benchmarks can drive the whole
detect → quarantine → re-plan → repair pipeline end to end.

Design points:

* **Deterministic** — every fault is drawn from a seeded
  ``np.random.default_rng``; the same seed replays the same fault
  sequence, so chaos tests shrink and failures reproduce.
* **Functional corruption** — faults rebind ``Replica.cols[...]`` /
  ``mins`` / ``checksums`` via ``.at[...].set`` updates.  Lazy stores
  alias column arrays across replicas until a commit diverges them;
  a functional update corrupts ONLY the targeted replica, exactly like
  a single datanode's disk going bad.  Already-gathered reader inputs
  (the ``BlockCache``, in-flight dispatches) keep their clean copies —
  disk rot does not reach the page cache.
* **Composes with fail-stop** — ``kill_node`` records a node death
  through the same ``Namenode`` liveness path ``run_job(fail_node_at=)``
  uses, so corruption and node failure can interleave in one scenario.

The typed errors live here (not in ``query``) so ``store``/``mapreduce``/
``runtime`` can all raise/catch them without import cycles:

* ``CorruptBlockError`` — a read-path checksum (or root-directory
  consistency) verification failed for one (replica, block, column).
  Carries the identity the recovery path needs to quarantine + re-plan.
* ``UnrecoverableDataError`` — every replica of some block is dead or
  quarantined, or the bounded re-plan retry budget is exhausted: the
  caller gets a clean typed failure, never silent wrong rows.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np


class CorruptBlockError(RuntimeError):
    """Read-path verification failed for one (replica, block, column).

    ``col`` is the column whose chunk checksums mismatched, or the
    sentinel ``"__root__"`` when the block's root directory disagreed
    with its sorted key column (a stale/corrupt index directory would
    silently mis-prune partitions — caught by the consistency check).
    """

    def __init__(self, replica_id: int, block_id: int, col: str,
                 node: Optional[int] = None):
        super().__init__(
            f"corrupt block: replica {replica_id}, block {block_id}, "
            f"col {col!r}" + (f", node {node}" if node is not None else ""))
        self.replica_id = replica_id
        self.block_id = block_id
        self.col = col
        self.node = node


class UnrecoverableDataError(RuntimeError):
    """No healthy replica can serve a block (all dead/quarantined), or the
    bounded re-plan retry budget ran out.  Subclasses RuntimeError so
    callers of the pre-existing ``plan()`` contract keep working."""


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Knobs for the executor-side corruption/failover recovery loop.

    ``max_retries``: re-plan attempts PER BLOCK within one job/flush
    (corruption retries and node-failure retries share the counter) —
    exceeding it raises ``UnrecoverableDataError`` instead of looping
    while replicas keep dying.  ``scrub``: run the store's attached
    background scrubber at the job/flush boundary.
    """
    max_retries: int = 3
    scrub: bool = True


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault (the injector's replayable audit trail)."""
    kind: str                      # chunk | column | root | checksum | node
    replica_id: int
    block_id: int
    col: Optional[str] = None
    node: Optional[int] = None


class FaultInjector:
    """Deterministic corruption driver for one ``BlockStore``.

    All mutations are silent — no checksum is updated, no cache is
    invalidated — because that is what real corruption does.  Detection
    must come from the read path / scrubber, which is the point.
    """

    def __init__(self, store, seed: int = 0):
        self.store = store
        self.rng = np.random.default_rng(seed)
        self.events: list[FaultEvent] = []

    # -- helpers ------------------------------------------------------------

    def _pick_col(self, replica_id: int, col: Optional[str]) -> str:
        if col is not None:
            return col
        names = sorted(self.store.replicas[replica_id].cols)
        return names[int(self.rng.integers(len(names)))]

    def _log(self, ev: FaultEvent) -> FaultEvent:
        self.events.append(ev)
        return ev

    # -- corruption primitives ---------------------------------------------

    def corrupt_chunk(self, replica_id: int, block_id: int,
                      col: Optional[str] = None) -> FaultEvent:
        """Flip ONE bit of one value in a column of a block — the smallest
        detectable fault.  A single bit flip changes a byte by ±2^k with
        k < 8, which can never cancel mod 65521, so the Fletcher-style
        chunk checksum is GUARANTEED to mismatch."""
        col = self._pick_col(replica_id, col)
        rep = self.store.replicas[replica_id]
        arr = rep.cols[col]
        pos = int(self.rng.integers(arr.shape[1]))
        bit = int(self.rng.integers(31))
        old = int(np.asarray(arr[block_id, pos]))
        rep.cols[col] = arr.at[block_id, pos].set(
            jnp.asarray(old ^ (1 << bit), arr.dtype))
        return self._log(FaultEvent("chunk", replica_id, block_id, col))

    def corrupt_column(self, replica_id: int, block_id: int,
                       col: Optional[str] = None) -> FaultEvent:
        """Overwrite a block's whole column with random garbage (a torn
        PAX minipage)."""
        col = self._pick_col(replica_id, col)
        rep = self.store.replicas[replica_id]
        arr = rep.cols[col]
        junk = self.rng.integers(0, 2**31 - 1, arr.shape[1], dtype=np.int32)
        rep.cols[col] = arr.at[block_id].set(
            jnp.asarray(junk, arr.dtype))
        return self._log(FaultEvent("column", replica_id, block_id, col))

    def corrupt_root(self, replica_id: int, block_id: int) -> FaultEvent:
        """Scramble a block's root directory (index mins).  Checksums do
        not cover the directory — detection relies on the read path's
        root-consistency check against the sorted key column."""
        rep = self.store.replicas[replica_id]
        shift = int(self.rng.integers(1, 1 << 20))
        rep.mins = rep.mins.at[block_id].add(jnp.int32(shift))
        return self._log(FaultEvent("root", replica_id, block_id,
                                    "__root__"))

    def truncate_checksums(self, replica_id: int, block_id: int,
                           col: Optional[str] = None) -> FaultEvent:
        """Zero a block's stored checksums for one column — the analogue
        of a truncated/stale checksum file.  The DATA is intact, but the
        read path cannot prove it: the block is treated as corrupt and
        repaired from a healthy replica (fresh checksums included)."""
        col = self._pick_col(replica_id, col)
        rep = self.store.replicas[replica_id]
        rep.checksums[col] = rep.checksums[col].at[block_id].set(
            jnp.uint32(0))
        return self._log(FaultEvent("checksum", replica_id, block_id, col))

    def corrupt_replicas(self, block_id: int, n_replicas: int,
                         col: Optional[str] = None) -> list[FaultEvent]:
        """Chaos helper: corrupt ``n_replicas`` DISTINCT replicas of one
        block (chunk flips).  ``n_replicas == R`` makes the block
        unrecoverable by construction."""
        rids = self.rng.permutation(self.store.replication)[:n_replicas]
        return [self.corrupt_chunk(int(r), block_id, col) for r in rids]

    # -- fail-stop composition ---------------------------------------------

    def kill_node(self, node: int) -> FaultEvent:
        """Fail-stop a datanode through the namenode liveness path — the
        same mechanism ``run_job(fail_node_at=...)`` injects, so chaos
        scenarios can interleave corruption with node death."""
        self.store.namenode.kill_node(node)
        return self._log(FaultEvent("node", -1, -1, node=node))
