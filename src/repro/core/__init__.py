"""HAIL core: the paper's contribution as a composable JAX data plane."""
from repro.core.governor import AccessLog, GovernorConfig, IndexGovernor, govern  # noqa: F401
from repro.core.index import PARTITION, ClusteredIndex  # noqa: F401
from repro.core.mapreduce import ClusterModel, JobStats, run_job  # noqa: F401
from repro.core.query import HailQuery, hail_annotation, plan  # noqa: F401
from repro.core.schema import SYNTHETIC, USERVISITS, Schema  # noqa: F401
from repro.core.store import BlockStore, Namenode  # noqa: F401
from repro.core.upload import hail_upload, hadooppp_upload, hdfs_upload  # noqa: F401
