"""Upload pipelines: HAIL vs HDFS(Hadoop) vs Hadoop++ (paper §3, §6.3).

HAIL (one pass, everything piggy-backed):
  parse ASCII -> binary PAX once on the client, then per replica r:
  sort by key_r (bad records to the tail) -> gather all columns ->
  build sparse root index -> recompute per-replica checksums.
  No re-read of the data: the sort/index ride the upload pipeline.

Hadoop (HDFS): store the raw ASCII block R times + chunk checksums.  No
parse, no index — query time pays the full parse+scan.

Hadoop++: Hadoop upload first, THEN an extra MapReduce job re-reads every
replica, parses, sorts by ONE global key and rewrites + re-checksums —
the extra read+write per replica the paper charges it with (§5).

All pipelines are jit'd per-block tensor programs vmapped over blocks, so
measured wall-clock ratios are real compute ratios; byte counts feed the
disk/network model in the benchmarks.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checksum as ck
from repro.core import index as idx
from repro.core import parse as ps
from repro.core.schema import ROWID, Schema
from repro.core.store import (BlockStore, Namenode, Replica, ReplicaInfo,
                              assign_nodes)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def _note_upload(kind: str, t0: float, stats: UploadStats):
    """Fold one finished upload into the flight recorder: an X slice per
    measured phase on the upload track plus the registry counters."""
    start = t0
    for phase, wall in stats.phases.items():
        obs_trace.complete_wall(f"upload:{phase}", start, wall,
                                track="upload",
                                args={"kind": kind,
                                      "ascii_bytes": stats.ascii_bytes,
                                      "written_bytes": stats.written_bytes})
        start += wall
    obs_metrics.observe_upload(kind, stats)


@dataclasses.dataclass
class UploadStats:
    wall_s: float                 # measured compute; == sum(phases.values())
    ascii_bytes: int              # bytes received by the client
    written_bytes: int            # bytes written across all replicas
    extra_read_bytes: int = 0     # Hadoop++ post-hoc job re-reads (modeled
    #   I/O — charged ONCE, by the disk model, never also as compute wall)
    n_indexes: int = 0
    phases: dict = dataclasses.field(default_factory=dict)
    # ^ explicit per-phase measured walls, e.g. {"hdfs": ..,
    #   "trojan_rewrite": ..} — see EXPERIMENTS.md


# ---------------------------------------------------------------------------
# HAIL
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _hail_pipeline(schema: Schema, sort_keys: tuple, partition_size: int):
    """Cached jit wrapper per (schema, keys, partition) — repeat uploads of
    the same shape reuse the compiled pipeline, so warm-up calls actually
    warm and measured upload walls compare compute, not trace+compile."""
    return jax.jit(jax.vmap(
        functools.partial(_hail_block, schema, sort_keys=sort_keys,
                          partition_size=partition_size)))


@functools.lru_cache(maxsize=None)
def _lazy_pipeline(schema: Schema):
    return jax.jit(jax.vmap(functools.partial(_lazy_block, schema)))


def _hail_block(schema: Schema, raw, block_id, sort_keys, partition_size):
    """Per-block pipeline; raw (rows, row_width) u8."""
    cols, bad = ps.parse_block(schema, raw)
    cols[ROWID] = (block_id * raw.shape[0]
                   + jnp.arange(raw.shape[0], dtype=jnp.int32))
    replicas = []
    for key in sort_keys:
        if key is None:
            perm = jnp.arange(raw.shape[0], dtype=jnp.int32)
        else:
            perm = idx.sort_permutation(cols[key], bad)
        sorted_cols = {k: v[perm] for k, v in cols.items()}
        mins = (idx.build_root(sorted_cols[key], partition_size)
                if key is not None else jnp.zeros((raw.shape[0] // partition_size,), jnp.int32))
        sums = ck.block_checksums(sorted_cols)
        replicas.append((sorted_cols, mins, sums))
    return replicas, bad


def hail_upload(schema: Schema, raw_blocks: np.ndarray,
                sort_keys: Optional[Sequence[Optional[str]]] = None,
                partition_size: int = idx.PARTITION,
                n_nodes: int = 10, *,
                index_columns: Optional[Sequence[str]] = None,
                replication: Optional[int] = None
                ) -> tuple[BlockStore, UploadStats]:
    """raw_blocks (n_blocks, rows, row_width) uint8.

    ``sort_keys`` (alias ``index_columns``): one entry per replica; ``None``
    entries ship that replica unindexed.  The EMPTY sequence
    (``index_columns=()``) is the LAZY fast path: parse + checksum once,
    replicate ``replication`` times (default 3) with NO sort and NO index —
    blocks are indexed later, incrementally, by adaptive jobs
    (``run_job(adaptive=AdaptiveConfig(...))``).  With non-empty keys the
    replica count IS ``len(sort_keys)``; a conflicting ``replication`` is
    rejected rather than silently ignored.
    """
    if index_columns is not None:
        sort_keys = index_columns
    assert sort_keys is not None, "pass sort_keys or index_columns"
    sort_keys = tuple(sort_keys)
    if len(sort_keys) == 0:
        return hail_lazy_upload(schema, raw_blocks,
                                3 if replication is None else replication,
                                partition_size, n_nodes)
    if replication is not None and replication != len(sort_keys):
        raise ValueError(
            f"replication={replication} conflicts with {len(sort_keys)} "
            f"sort_keys — replica count is len(sort_keys) on the eager path")
    n_blocks, rows, width = raw_blocks.shape
    fn = _hail_pipeline(schema, sort_keys, partition_size)
    t0 = time.perf_counter()
    reps, bad = fn(jnp.asarray(raw_blocks),
                   jnp.arange(n_blocks, dtype=jnp.int32))
    jax.block_until_ready(reps)
    wall = time.perf_counter() - t0
    bad_counts = bad.sum(axis=1).astype(jnp.int32)

    nodes = assign_nodes(n_blocks, len(sort_keys), n_nodes)
    namenode = Namenode()
    replicas = []
    written = 0
    for r, (cols, mins, sums) in enumerate(reps):
        rep = Replica(sort_key=sort_keys[r], cols=cols, mins=mins,
                      checksums=sums, nodes=nodes[r])
        replicas.append(rep)
        written += rep.nbytes
        per_block_bytes = rep.nbytes // n_blocks
        for b in range(n_blocks):
            namenode.register(ReplicaInfo(
                block_id=b, node=int(nodes[r, b]), sort_key=sort_keys[r],
                partition_size=partition_size, n_rows=rows, layout="pax",
                nbytes=per_block_bytes))
    store = BlockStore(schema=schema, n_blocks=n_blocks, rows_per_block=rows,
                       partition_size=partition_size, replicas=replicas,
                       bad_counts=bad_counts, namenode=namenode, layout="pax",
                       bad_original=bad)
    stats = UploadStats(wall_s=wall, ascii_bytes=raw_blocks.size,
                        written_bytes=written,
                        n_indexes=sum(k is not None for k in sort_keys),
                        phases={"hail": wall})
    _note_upload("hail", t0, stats)
    return store, stats


def _lazy_block(schema: Schema, raw, block_id):
    """Per-block LAZY pipeline: parse + rowid + checksums — no sort/index."""
    cols, bad = ps.parse_block(schema, raw)
    cols[ROWID] = (block_id * raw.shape[0]
                   + jnp.arange(raw.shape[0], dtype=jnp.int32))
    return cols, ck.block_checksums(cols), bad


def hail_lazy_upload(schema: Schema, raw_blocks: np.ndarray,
                     replication: int = 3,
                     partition_size: int = idx.PARTITION,
                     n_nodes: int = 10) -> tuple[BlockStore, UploadStats]:
    """Adaptive-HAIL upload (LIAH): ship PAX blocks UNINDEXED.

    One parse + one checksum pass serve all replicas (identical bytes until
    a replica is adaptively sorted), so upload pays neither the per-replica
    sort nor the index build — that work is earned back incrementally by
    ``run_job(adaptive=...)`` piggybacking on full-scan map tasks.  Replicas
    start unclaimed (``sort_key=None``, ``indexed`` all-False) with zeroed
    root directories sized for ``partition_size``.
    """
    n_blocks, rows, width = raw_blocks.shape
    fn = _lazy_pipeline(schema)
    t0 = time.perf_counter()
    cols, sums, bad = fn(jnp.asarray(raw_blocks),
                         jnp.arange(n_blocks, dtype=jnp.int32))
    jax.block_until_ready(bad)
    wall = time.perf_counter() - t0
    bad_counts = bad.sum(axis=1).astype(jnp.int32)

    nodes = assign_nodes(n_blocks, replication, n_nodes)
    namenode = Namenode()
    replicas = []
    written = 0
    zero_mins = jnp.zeros((n_blocks, rows // partition_size), jnp.int32)
    for r in range(replication):
        # per-replica dicts (commit rebinds entries per replica); the column
        # arrays alias until an adaptive commit diverges them functionally
        rep = Replica(sort_key=None, cols=dict(cols), mins=zero_mins,
                      checksums=dict(sums), nodes=nodes[r])
        replicas.append(rep)
        written += rep.nbytes
        per_block_bytes = rep.nbytes // n_blocks
        for b in range(n_blocks):
            namenode.register(ReplicaInfo(
                block_id=b, node=int(nodes[r, b]), sort_key=None,
                partition_size=partition_size, n_rows=rows, layout="pax",
                nbytes=per_block_bytes))
    store = BlockStore(schema=schema, n_blocks=n_blocks, rows_per_block=rows,
                       partition_size=partition_size, replicas=replicas,
                       bad_counts=bad_counts, namenode=namenode, layout="pax",
                       bad_original=bad)
    stats = UploadStats(wall_s=wall, ascii_bytes=raw_blocks.size,
                        written_bytes=written, n_indexes=0,
                        phases={"hail_lazy": wall})
    _note_upload("hail_lazy", t0, stats)
    return store, stats


# ---------------------------------------------------------------------------
# Hadoop (plain HDFS)
# ---------------------------------------------------------------------------


def hdfs_upload(schema: Schema, raw_blocks: np.ndarray, replication: int = 3,
                n_nodes: int = 10) -> tuple[BlockStore, UploadStats]:
    """Raw ASCII replicated R times; checksums only (what HDFS computes)."""
    n_blocks, rows, width = raw_blocks.shape
    raw = jnp.asarray(raw_blocks)
    sums_fn = jax.jit(jax.vmap(ck.chunk_checksums))
    t0 = time.perf_counter()
    sums = sums_fn(raw.reshape(n_blocks, -1))
    jax.block_until_ready(sums)
    wall = time.perf_counter() - t0

    nodes = assign_nodes(n_blocks, replication, n_nodes)
    namenode = Namenode()
    replicas = []
    for r in range(replication):
        rep = Replica(sort_key=None, cols={"__raw__": raw}, mins=None,
                      checksums={"__raw__": sums}, nodes=nodes[r])
        replicas.append(rep)
        for b in range(n_blocks):
            namenode.register(ReplicaInfo(
                block_id=b, node=int(nodes[r, b]), sort_key=None,
                partition_size=0, n_rows=rows, layout="row_ascii",
                nbytes=rows * width))
    store = BlockStore(schema=schema, n_blocks=n_blocks, rows_per_block=rows,
                       partition_size=0, replicas=replicas,
                       bad_counts=jnp.zeros((n_blocks,), jnp.int32),
                       namenode=namenode, layout="row_ascii")
    stats = UploadStats(wall_s=wall, ascii_bytes=raw_blocks.size,
                        written_bytes=raw_blocks.size * replication,
                        phases={"hdfs": wall})
    _note_upload("hdfs", t0, stats)
    return store, stats


# ---------------------------------------------------------------------------
# Hadoop++ (trojan index: post-hoc MapReduce job, one global sort key)
# ---------------------------------------------------------------------------


def hadooppp_upload(schema: Schema, raw_blocks: np.ndarray, sort_key: str,
                    replication: int = 3, partition_size: int = idx.PARTITION,
                    n_nodes: int = 10) -> tuple[BlockStore, UploadStats]:
    # phase 1: plain HDFS upload (pays checksum pass over raw bytes)
    _, s1 = hdfs_upload(schema, raw_blocks, replication, n_nodes)
    # phase 2: the trojan-index MapReduce job re-reads every replica, parses,
    # sorts by the ONE key, rewrites every replica.  The REWRITE compute is
    # measured (the HAIL-style pipeline below); the RE-READ is disk I/O and
    # is charged exactly once, as ``extra_read_bytes`` through the disk
    # model (upload_model_seconds) — the seed double-counted it by timing a
    # simulated checksum re-read AND re-running the full upload's compute.
    keys = tuple([sort_key] * replication)
    store, s2 = hail_upload(schema, raw_blocks, keys, partition_size, n_nodes)
    phases = {"hdfs": s1.wall_s, "trojan_rewrite": s2.wall_s}
    stats = UploadStats(
        wall_s=sum(phases.values()),
        ascii_bytes=s1.ascii_bytes,
        written_bytes=s1.written_bytes + s2.written_bytes,
        extra_read_bytes=s1.written_bytes,  # job re-reads each replica
        n_indexes=1,
        phases=phases)
    obs_metrics.observe_upload("hadooppp", stats)
    return store, stats
