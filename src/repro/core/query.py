"""HAIL query pipeline (paper §4): annotations, replica planning, record
readers (index scan vs full scan), PAX->row reconstruction.

Replica selection mirrors §4.3: for each block, prefer an *alive* replica
whose clustered index matches the filter attribute; otherwise fall back to
any alive replica with a full scan (failover path — Fig 8's experiment).

Record readers are jit'd, *batched over many blocks per call* — that batching
is exactly what HailSplitting enables (ONE dispatch per split instead of one
per block); the benchmarks measure both policies.  Two properties keep the
hot path dispatch- and compile-free:

* (lo, hi) are TRACED arguments everywhere (SMEM runtime scalars for the
  Pallas readers, ordinary traced scalars for the jnp readers), so a
  compiled reader is reused across every query against the same store
  shape — zero per-query recompiles;
* ``read_hail_kernels`` issues exactly one fused ``hail_read`` pallas_call
  per split regardless of block count, including MIXED-replica and failover
  splits (per-block ``use_index`` flags select pruned index scan vs full
  scan inside the kernel);
* ``read_hail_batch`` extends that to a QUERY dimension: one pallas_call
  serves a whole batch of compatible concurrent queries (same filter
  column, same projection) with per-query match masks — the HailServer's
  shared-scan hot path — optionally through the store's hot-block cache
  (``core/cache.BlockCache``), whose traffic still feeds the governor's
  AccessLog.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import governor as gov
from repro.core import index as idx
from repro.core import parse as ps
from repro.core.fault import CorruptBlockError, UnrecoverableDataError
from repro.core.schema import ROWID, Schema
from repro.core.store import BlockStore
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class HailQuery:
    """filter: (column, lo, hi) inclusive range (point = lo==hi)."""
    filter: Optional[tuple[str, int, int]]
    projection: tuple[str, ...]

    @property
    def filter_col(self) -> Optional[str]:
        return self.filter[0] if self.filter else None


def hail_annotation(schema: Schema, filter: str = "", projection: str = ""):
    """Parse the paper's @HailQuery annotation syntax:

      @HailQuery(filter="@3 between(7305,7670)", projection={@1})
      filter forms: "@k between(a,b)" | "@k = v"   (@k is 1-based position)
    """
    flt = None
    if filter:
        m = re.match(r"@(\d+)\s+between\((-?\d+),\s*(-?\d+)\)", filter.strip())
        if m:
            col = schema.columns[int(m.group(1)) - 1].name
            flt = (col, int(m.group(2)), int(m.group(3)))
        else:
            m = re.match(r"@(\d+)\s*=\s*(-?\d+)", filter.strip())
            if not m:
                raise ValueError(f"bad filter annotation: {filter!r}")
            col = schema.columns[int(m.group(1)) - 1].name
            v = int(m.group(2))
            flt = (col, v, v)
    proj = tuple(schema.columns[int(p) - 1].name
                 for p in re.findall(r"@(\d+)", projection))
    return HailQuery(filter=flt, projection=proj or schema.names)


def hail_query(filter: str = "", projection: str = "", schema: Schema = None):
    """Decorator flavour: @hail_query(filter=..., projection=...) on a map fn."""
    def deco(fn):
        fn.__hail_query__ = hail_annotation(schema, filter, projection)
        return fn
    return deco


# ---------------------------------------------------------------------------
# Planning (the JobClient/JobTracker side)
# ---------------------------------------------------------------------------

FULL_SCAN = -1


@dataclasses.dataclass
class QueryPlan:
    replica_for_block: np.ndarray    # (n_blocks,) replica idx used for reading
    index_scan: np.ndarray           # (n_blocks,) bool: index scan possible
    nodes: np.ndarray                # (n_blocks,) datanode serving the read


def plan(store: BlockStore, query: HailQuery) -> QueryPlan:
    """Replica selection against the store's LIVE per-block index state.

    A replica qualifies a block for index scan only if its clustered index
    both matches the filter attribute AND has actually been built for that
    block (``Replica.block_indexed``) — under adaptive indexing blocks of
    the same replica flip from full scan to index scan as running jobs
    commit indexes, and re-planning picks that up job over job.
    """
    nb = store.n_blocks
    rep = np.zeros(nb, dtype=np.int64)
    is_idx = np.zeros(nb, dtype=bool)
    nodes = np.zeros(nb, dtype=np.int64)
    want = query.filter_col
    for b in range(nb):
        alive = store.alive_replica_ids(b)
        if not alive:
            raise UnrecoverableDataError(
                f"block {b}: all replicas lost or quarantined")
        choice = None
        if want is not None and store.layout == "pax":
            for i in alive:
                if (store.replicas[i].sort_key == want
                        and store.replicas[i].block_indexed(b)):
                    choice = i
                    is_idx[b] = True
                    break
        if choice is None:
            choice = alive[0]
        rep[b] = choice
        nodes[b] = int(store.replicas[choice].nodes[b])
    return QueryPlan(replica_for_block=rep, index_scan=is_idx, nodes=nodes)


# ---------------------------------------------------------------------------
# Record readers (jit'd, batched over blocks)
# ---------------------------------------------------------------------------


# lo/hi are TRACED: ten different query ranges = one compilation.
@functools.partial(jax.jit, static_argnames=("partition_size",))
def _index_read(sorted_key, mins, bad, lo, hi, *, partition_size: int):
    f = jax.vmap(lambda k, m, b: idx.index_scan_mask(k, m, lo, hi,
                                                     partition_size) & ~b)
    mask = f(sorted_key, mins, bad)
    g = jax.vmap(lambda m: idx.rows_read_fraction(m, lo, hi, partition_size,
                                                  sorted_key.shape[1]))
    return mask, g(mins)


@jax.jit
def _full_read(key_col, bad, lo, hi):
    return jax.vmap(lambda k, b: idx.full_scan_mask(k, lo, hi) & ~b)(key_col, bad)


@dataclasses.dataclass
class ReadResult:
    """Fixed-shape result: projected columns + qualifying mask."""
    cols: dict[str, jax.Array]     # col -> (n_blocks, rows)
    mask: jax.Array                # (n_blocks, rows) bool
    rows_read_frac: jax.Array      # (n_blocks,) I/O model input
    bytes_read: "int | jax.Array"  # modeled bytes (index scan reads less);
    # may be a LAZY 0-d array so building a ReadResult never forces a
    # device sync — run_job materializes it at the completion barrier


def _bad_mask(store: BlockStore, replica: int) -> jax.Array:
    """Bad rows sit at the tail of INDEXED blocks (sorted there); for a
    block that is still unindexed they stay at their original upload
    positions — under adaptive indexing one replica mixes both, per block.
    Cached per (store, replica); ``commit_block_indexes`` invalidates the
    entry when a job flips blocks from upload order to sorted."""
    cache = store.__dict__.setdefault("_bad_mask_cache", {})
    if replica in cache:
        return cache[replica]
    rep = store.replicas[replica]
    orig = (store.bad_original if store.bad_original is not None
            else jnp.zeros((store.n_blocks, store.rows_per_block), bool))
    if rep.sort_key is None:
        m = orig
    else:
        r = jnp.arange(store.rows_per_block, dtype=jnp.int32)[None, :]
        tail = r >= (store.rows_per_block - store.bad_counts[:, None])
        if rep.indexed.all():
            m = tail
        else:
            m = jnp.where(jnp.asarray(rep.indexed)[:, None], tail, orig)
    cache[replica] = m
    return m


def _verify_replica_blocks(store: BlockStore, rid: int, bsel, names):
    """Read-path integrity gate for one replica's blocks (§3.2: HDFS always
    verifies chunk checksums on read; HAIL keeps that working with
    per-replica checksums).  Verifies exactly the columns this read will
    touch in ONE batched device dispatch, plus root-directory consistency
    (mins re-derived from the now-verified key column) for indexed blocks
    when the read uses the index.  Raises ``CorruptBlockError`` carrying the
    first failing (replica, block, col) — the executor quarantines it and
    re-plans.  Gated by ``store.verify_reads``; callers on the cached path
    invoke this only on BlockCache FILLS, so hits pay nothing."""
    if not store.verify_reads or store.layout != "pax":
        return
    from repro.kernels import ops
    rep = store.replicas[rid]
    names = tuple(dict.fromkeys(names))
    bsel = np.asarray(bsel)
    data = jnp.stack([rep.cols[c][bsel] for c in names])
    sums = jnp.stack([rep.checksums[c][bsel] for c in names])
    ok = np.asarray(ops.verify_blocks(data, sums))
    if not ok.all():
        ci, bi = np.argwhere(~ok)[0]
        ops.DISPATCH_COUNTS["verify_failures"] += 1
        b = int(bsel[bi])
        raise CorruptBlockError(rid, b, names[ci], int(rep.nodes[b]))
    if rep.sort_key in names:
        isel = np.asarray(rep.indexed[bsel], bool)
        if isel.any():
            sub = bsel[isel]
            rok = np.asarray(ops.verify_root(
                rep.mins[sub], rep.cols[rep.sort_key][sub],
                partition_size=store.partition_size))
            if not rok.all():
                ops.DISPATCH_COUNTS["verify_failures"] += 1
                b = int(sub[np.argwhere(~rok)[0][0]])
                raise CorruptBlockError(rid, b, "__root__",
                                        int(rep.nodes[b]))


def read_hail(store: BlockStore, query: HailQuery, qplan: QueryPlan,
              block_ids: Sequence[int] | None = None) -> ReadResult:
    """HAIL record reader over (a subset of) blocks, per-replica batched.

    Assembly is GATHER-based: per-replica batches are concatenated in
    replica order and restored to input order with one inverse-permutation
    take per array — no per-group ``.at[sel].set`` scatters on the hot path.
    """
    nb = store.n_blocks
    ids = np.arange(nb) if block_ids is None else np.asarray(block_ids)
    rows = store.rows_per_block
    proj_cols = query.projection + (ROWID,)
    if len(ids) == 0:                # degenerate split: empty fixed-shape result
        tmpl = store.template_replica()
        return ReadResult(
            cols={c: jnp.zeros((0, rows), tmpl.cols[c].dtype)
                  for c in proj_cols},
            mask=jnp.zeros((0, rows), bool),
            rows_read_frac=jnp.zeros((0,), jnp.float32), bytes_read=0)
    from repro.kernels import ops
    col_bytes = 4 * rows
    bytes_read = jnp.zeros((), jnp.float32)   # lazy: no sync at dispatch
    order: list[np.ndarray] = []     # input positions, concatenation order
    masks, fracs = [], []
    cols_parts: dict[str, list] = {c: [] for c in proj_cols}
    for rid in np.unique(qplan.replica_for_block[ids]):
        sel = np.nonzero(qplan.replica_for_block[ids] == rid)[0]
        bsel = ids[sel]
        rep = store.replicas[int(rid)]
        _verify_replica_blocks(
            store, int(rid), bsel,
            (proj_cols if query.filter is None
             else (query.filter[0],) + proj_cols))
        bad = _bad_mask(store, int(rid))[bsel]
        use_index = bool(qplan.index_scan[bsel].all()) and query.filter is not None
        if query.filter is not None:
            kind = "index_scan_blocks" if use_index else "full_scan_blocks"
            ops.DISPATCH_COUNTS[kind] += len(bsel)
            col, lo, hi = query.filter
            # per-column attribution: reader_stats + the store's AccessLog
            # (the governor's LRU eviction signal)
            gov.attribute_read(store, int(rid), col,
                               len(bsel) if use_index else 0,
                               0 if use_index else len(bsel))
            if use_index:
                m, fr = _index_read(rep.cols[col][bsel], rep.mins[bsel], bad,
                                    lo, hi,
                                    partition_size=store.partition_size)
                fr = fr.astype(jnp.float32)
            else:
                m = _full_read(rep.cols[col][bsel], bad, lo, hi)
                fr = jnp.ones((len(bsel),), jnp.float32)
        else:
            m = ~bad
            fr = jnp.ones((len(bsel),), jnp.float32)
        # modeled I/O: filter column read per partition range; projected
        # columns read for qualifying partitions only (PAX pruning)
        bytes_read += fr.sum() * col_bytes * (1 + len(query.projection))
        order.append(sel)
        masks.append(m)
        fracs.append(fr)
        for c in proj_cols:
            cols_parts[c].append(rep.cols[c][bsel])
    inv = np.empty(len(ids), dtype=np.int64)
    inv[np.concatenate(order)] = np.arange(len(ids))
    if len(order) == 1:              # single replica: concat+gather is a noop
        mask, frac = masks[0], fracs[0]
        out_cols = {c: v[0] for c, v in cols_parts.items()}
    else:
        mask = jnp.concatenate(masks, axis=0)[inv]
        frac = jnp.concatenate(fracs, axis=0)[inv]
        out_cols = {c: jnp.concatenate(v, axis=0)[inv]
                    for c, v in cols_parts.items()}
    return ReadResult(cols=out_cols, mask=mask, rows_read_frac=frac,
                      bytes_read=bytes_read)


def _gather_replica_inputs(store: BlockStore, rid: int, bsel: np.ndarray,
                           col: str, proj_cols: tuple):
    """Decoded reader inputs for one replica's blocks: (keys, stacked
    projection, bad mask, root directories).

    When the store carries a hot-block cache (``core/cache.BlockCache``,
    attached by the HailServer) the gathered device arrays are served from
    it — this host-side gather + stack is exactly the per-read work the
    cache removes for hot splits.  The cache is invalidated per replica by
    ``commit_block_indexes`` / ``demote_replica``, so a hit can never
    observe a half-committed replica."""
    cache = store.block_cache
    key = (rid, tuple(int(b) for b in bsel), col, proj_cols)
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            obs_trace.instant("block_cache_hit", track="cache",
                              args={"replica": rid, "blocks": len(bsel)})
            return hit
    rep = store.replicas[rid]
    # verify on FILL, not on hit: cached gathers are separate device arrays
    # already proven against the stored checksums, so hot splits pay zero
    # verification cost (the clean-path overhead bound in bench_fault)
    with obs_trace.span("cache_fill", track="cache",
                        args={"replica": rid, "blocks": len(bsel)}):
        _verify_replica_blocks(store, rid, bsel, (col,) + proj_cols)
        val = (rep.cols[col][bsel],
               jnp.stack([rep.cols[c][bsel] for c in proj_cols], axis=-1),
               _bad_mask(store, rid)[bsel],
               rep.mins[bsel])
    if cache is not None:
        cache.put(key, val)
    return val


def _gather_split_inputs(store: BlockStore, qplan: QueryPlan,
                         ids: np.ndarray, col: str, proj_cols: tuple,
                         n_queries: int = 1):
    """Per-block kernel inputs for a split, replica-batched and restored to
    input order with one inverse-permutation take per array (no per-group
    ``.at[sel].set`` scatters on the hot path) — shared by the single-query
    and shared-scan fused readers.

    Attribution: each replica group is charged ``n_queries`` reads (one per
    query sharing the scan) through ``governor.attribute_read`` — cached or
    not, batched or not, the governor's AccessLog sees the same totals as
    ``n_queries`` serial jobs."""
    rids = qplan.replica_for_block[ids]
    order, keys_p, proj_p, bad_p, mins_p, uidx_p = [], [], [], [], [], []
    for rid in np.unique(rids):
        sel = np.nonzero(rids == rid)[0]
        bsel = ids[sel]
        n_idx = int(np.asarray(qplan.index_scan[bsel], bool).sum())
        for _ in range(n_queries):
            gov.attribute_read(store, int(rid), col, n_idx,
                               len(bsel) - n_idx)
        k, p, b, m = _gather_replica_inputs(store, int(rid), bsel, col,
                                            proj_cols)
        order.append(sel)
        keys_p.append(k)
        proj_p.append(p)
        bad_p.append(b)
        mins_p.append(m)
        uidx_p.append(np.asarray(qplan.index_scan[bsel], np.int32))
    inv = np.empty(len(ids), dtype=np.int64)
    inv[np.concatenate(order)] = np.arange(len(ids))
    if len(order) == 1:              # single replica: concat+gather is a noop
        return (mins_p[0], keys_p[0], proj_p[0], bad_p[0], uidx_p[0])
    return (jnp.concatenate(mins_p, axis=0)[inv],
            jnp.concatenate(keys_p, axis=0)[inv],
            jnp.concatenate(proj_p, axis=0)[inv],
            jnp.concatenate(bad_p, axis=0)[inv],
            np.concatenate(uidx_p, axis=0)[inv])


def attribution_groups(qplan: QueryPlan, block_ids: Sequence[int]
                       ) -> tuple[tuple[int, int, int], ...]:
    """The per-replica (replica_id, index-scanned, full-scanned) block
    counts ``_gather_split_inputs`` charges ONE query for this split — the
    result cache stores this recipe with each materialized answer and the
    server replays it through ``governor.attribute_read`` on every hit, so
    cached traffic and scanned traffic feed the AccessLog identically."""
    ids = np.asarray(block_ids)
    rids = qplan.replica_for_block[ids]
    out = []
    for rid in np.unique(rids):
        bsel = ids[rids == rid]
        n_idx = int(np.asarray(qplan.index_scan[bsel], bool).sum())
        out.append((int(rid), n_idx, len(bsel) - n_idx))
    return tuple(out)


def _empty_read(store: BlockStore, proj_cols: tuple,
                rows: int) -> ReadResult:
    """Degenerate split: empty fixed-shape result."""
    tmpl = store.template_replica()
    return ReadResult(
        cols={c: jnp.zeros((0, rows), tmpl.cols[c].dtype)
              for c in proj_cols},
        mask=jnp.zeros((0, rows), bool),
        rows_read_frac=jnp.zeros((0,), jnp.float32), bytes_read=0)


def read_hail_kernels(store: BlockStore, query: HailQuery, qplan: QueryPlan,
                      block_ids: Sequence[int] | None = None) -> ReadResult:
    """Kernel-backed record reader: ONE fused ``hail_read`` pallas_call per
    split (interpret mode on CPU), regardless of block count or replica mix.

    The kernel reads each block's root directory, prunes row tiles outside
    the qualifying partition range (per-block ``use_index`` selects pruned
    index scan vs failover full scan), and masks bad rows — so mixed-replica
    splits and the per-block retry splits ``run_job`` re-plans after a node
    failure all go through the same single dispatch.  Semantics identical to
    read_hail — asserted end-to-end by tests/test_kernels.py and
    tests/test_fused_reader.py."""
    from repro.kernels import ops

    assert query.filter is not None and store.layout == "pax"
    col, lo, hi = query.filter
    ids = (np.arange(store.n_blocks) if block_ids is None
           else np.asarray(block_ids))
    rows = store.rows_per_block
    proj_cols = tuple(query.projection) + (ROWID,)
    if len(ids) == 0:
        return _empty_read(store, proj_cols, rows)

    mins, keys, proj, bad, uidx = _gather_split_inputs(store, qplan, ids,
                                                       col, proj_cols)
    # one dispatch for the whole split; lo/hi are runtime scalars; uidx
    # stays a host array so ops' scan-mode counters cost no device sync
    mask, out, frac = ops.hail_read(mins, keys, proj, bad, uidx,
                                    lo, hi,
                                    partition_size=store.partition_size)
    cols = {c: out[..., j] for j, c in enumerate(proj_cols)}
    col_bytes = 4 * rows
    return ReadResult(cols=cols, mask=mask, rows_read_frac=frac,
                      bytes_read=frac.sum() * col_bytes
                      * (1 + len(query.projection)))


def read_hail_batch(store: BlockStore, queries: Sequence[HailQuery],
                    qplan: QueryPlan,
                    block_ids: Sequence[int] | None = None
                    ) -> tuple[list[ReadResult], "int | jax.Array"]:
    """SHARED-SCAN record reader: ONE fused pallas_call serves a whole batch
    of compatible queries (same filter column, same projection, same plan)
    over a split — Q concurrent range queries cost one dispatch and one
    pass over the data instead of Q (the HailServer's hot path).

    Returns (one ReadResult per query, shared physical bytes).  The per-
    query results carry that query's own mask and rows-read fraction; the
    projection columns are SHARED device arrays masked by the union of the
    batch's masks, which is exact under each query's own mask (``collect``
    touches only mask-true rows).  The second return value models the
    PHYSICAL I/O of the shared scan — per block, the widest partition range
    any query in the batch needed (a lazy 0-d array; no sync at dispatch).
    """
    from repro.kernels import ops

    assert store.layout == "pax" and len(queries) >= 1
    col = queries[0].filter_col
    assert col is not None, "shared-scan batches need a range filter"
    proj = tuple(queries[0].projection)
    for qq in queries[1:]:
        assert qq.filter_col == col and tuple(qq.projection) == proj, \
            "batched queries must share filter column and projection"
    ids = (np.arange(store.n_blocks) if block_ids is None
           else np.asarray(block_ids))
    rows = store.rows_per_block
    proj_cols = proj + (ROWID,)
    col_bytes = 4 * rows
    if len(ids) == 0:
        return [_empty_read(store, proj_cols, rows) for _ in queries], 0

    mins, keys, proj_arr, bad, uidx = _gather_split_inputs(
        store, qplan, ids, col, proj_cols, n_queries=len(queries))
    lohi = np.asarray([[qq.filter[1], qq.filter[2]] for qq in queries],
                      np.int32)
    mask, out, frac = ops.hail_read_batch(mins, keys, proj_arr, bad, uidx,
                                          lohi,
                                          partition_size=store.partition_size)
    cols = {c: out[..., j] for j, c in enumerate(proj_cols)}
    results = [
        ReadResult(cols=cols, mask=mask[..., qi],
                   rows_read_frac=frac[:, qi],
                   bytes_read=frac[:, qi].sum() * col_bytes
                   * (1 + len(proj)))
        for qi in range(len(queries))]
    shared_bytes = frac.max(axis=1).sum() * col_bytes * (1 + len(proj))
    return results, shared_bytes


def gather_shared_scan_inputs(store: BlockStore,
                              queries: Sequence[HailQuery],
                              qplan: QueryPlan,
                              block_ids: Sequence[int]):
    """Pre-gathered fused-reader inputs for ONE split of a (possibly
    sharded) shared scan: (mins, keys, proj, bad, use_index).

    This is the host-side half of the fused read — BlockCache traffic,
    read-path checksum verification (raising ``CorruptBlockError`` exactly
    like the unsharded readers, so executors keep their quarantine/re-plan
    handling per split), and governor attribution all happen HERE; the wave
    executor then ships many splits' inputs in one sharded dispatch."""
    ids = np.asarray(block_ids)
    col = queries[0].filter_col
    assert col is not None and store.layout == "pax"
    proj_cols = tuple(queries[0].projection) + (ROWID,)
    return _gather_split_inputs(store, qplan, ids, col, proj_cols,
                                n_queries=len(queries))


def read_hail_batch_sharded(store: BlockStore,
                            queries: Sequence[HailQuery],
                            gathered: Sequence[tuple], mesh, axes
                            ) -> list[tuple[list[ReadResult],
                                            "int | jax.Array"]]:
    """SHARDED shared-scan reader: ONE shard_map'd fused dispatch serves a
    WAVE of up to n_dev splits, each split's block tile scanned on its own
    device against the batch's replicated (Q, 2) ranges.

    ``gathered`` holds per-split inputs from ``gather_shared_scan_inputs``
    (1 <= len <= n_dev).  Ragged splits are padded to the wave's max block
    count with DEAD blocks (bad=True rows — the kernel masks them to
    False) and the wave is padded to n_dev splits, so every device runs
    the identical program; outputs are sliced back per split, making the
    row-sets byte-identical to len(gathered) single-device dispatches.
    Returns one (results-per-query, shared_bytes) pair per split, shaped
    exactly like ``read_hail_batch``'s return value.
    """
    from repro.kernels import ops
    from repro.dist import sharding as dsh

    assert store.layout == "pax" and len(queries) >= 1
    col = queries[0].filter_col
    assert col is not None, "shared-scan batches need a range filter"
    proj = tuple(queries[0].projection)
    proj_cols = proj + (ROWID,)
    rows = store.rows_per_block
    col_bytes = 4 * rows
    n_dev = dsh.scan_device_count(mesh, axes)
    n_splits = len(gathered)
    assert 1 <= n_splits <= n_dev, (n_splits, n_dev)
    n_q = len(queries)
    lohi = np.asarray([[qq.filter[1], qq.filter[2]] for qq in queries],
                      np.int32)

    sizes = [int(g[0].shape[0]) for g in gathered]
    bmax = max(sizes)
    # scan-mode counters over REAL blocks only (padding must not skew the
    # serial-equivalent accounting); the sharded ops wrapper counts waves
    for g in gathered:
        u = np.asarray(g[4])
        n_idx = int(u.astype(bool).sum())
        ops.DISPATCH_COUNTS["index_scan_blocks"] += n_q * n_idx
        ops.DISPATCH_COUNTS["full_scan_blocks"] += n_q * (u.shape[0] - n_idx)

    def _pad(g):
        mins, keys, proj_a, bad, uidx = g
        extra = bmax - mins.shape[0]
        if extra == 0:
            return mins, keys, proj_a, bad, np.asarray(uidx, np.int32)
        return (jnp.concatenate(
                    [mins, jnp.zeros((extra,) + mins.shape[1:], mins.dtype)]),
                jnp.concatenate(
                    [keys, jnp.zeros((extra,) + keys.shape[1:], keys.dtype)]),
                jnp.concatenate(
                    [proj_a,
                     jnp.zeros((extra,) + proj_a.shape[1:], proj_a.dtype)]),
                jnp.concatenate(
                    [bad, jnp.ones((extra,) + bad.shape[1:], bool)]),
                np.concatenate([np.asarray(uidx, np.int32),
                                np.zeros((extra,), np.int32)]))

    padded = [_pad(g) for g in gathered]
    while len(padded) < n_dev:        # dead dummy splits fill the mesh
        mins0, keys0, proj0, bad0, _ = padded[0]
        padded.append((jnp.zeros_like(mins0), jnp.zeros_like(keys0),
                       jnp.zeros_like(proj0), jnp.ones_like(bad0),
                       np.zeros((bmax,), np.int32)))
    mins = jnp.concatenate([p[0] for p in padded], axis=0)
    keys = jnp.concatenate([p[1] for p in padded], axis=0)
    proj_arr = jnp.concatenate([p[2] for p in padded], axis=0)
    bad = jnp.concatenate([p[3] for p in padded], axis=0)
    uidx = np.concatenate([p[4] for p in padded], axis=0)

    mask, out, frac = ops.hail_read_batch_sharded(
        mins, keys, proj_arr, bad, uidx, lohi,
        partition_size=store.partition_size, mesh=mesh, axes=axes,
        n_splits=n_splits)

    outs = []
    for s in range(n_splits):
        sl = slice(s * bmax, s * bmax + sizes[s])
        cols = {c: out[sl, :, j] for j, c in enumerate(proj_cols)}
        m, fr = mask[sl], frac[sl]
        results = [
            ReadResult(cols=cols, mask=m[..., qi],
                       rows_read_frac=fr[:, qi],
                       bytes_read=fr[:, qi].sum() * col_bytes
                       * (1 + len(proj)))
            for qi in range(n_q)]
        shared = fr.max(axis=1).sum() * col_bytes * (1 + len(proj))
        outs.append((results, shared))
    return outs


@functools.lru_cache(maxsize=None)
def _hadoop_reader(schema, filter_col, projection):
    """Compiled parse+scan for (schema, filter col, projection) — (lo, hi)
    and the data are traced, so the parser compiles once per job SHAPE, not
    once per split per query (the seed rebuilt the jit closure per call)."""

    @jax.jit
    def go(raw, bids, lo, hi):
        def one(block, bid):
            cols, bad = ps.parse_block(schema, block)
            cols[ROWID] = (bid * block.shape[0]
                           + jnp.arange(block.shape[0], dtype=jnp.int32))
            if filter_col is not None:
                m = idx.full_scan_mask(cols[filter_col], lo, hi) & ~bad
            else:
                m = ~bad
            return {c: cols[c] for c in projection + (ROWID,)}, m

        return jax.vmap(one)(raw, bids)

    return go


def read_hadoop(store: BlockStore, query: HailQuery,
                block_ids: Sequence[int] | None = None) -> ReadResult:
    """Hadoop baseline: parse raw ASCII rows, then scan (row layout)."""
    assert store.layout == "row_ascii"
    ids = (np.arange(store.n_blocks) if block_ids is None
           else np.asarray(block_ids))
    raw = store.replicas[0].cols["__raw__"][ids]

    go = _hadoop_reader(store.schema, query.filter_col, query.projection)
    if query.filter is not None:
        _, lo, hi = query.filter
    else:
        lo = hi = 0
    cols, mask = go(raw, jnp.asarray(ids, jnp.int32),
                    jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32))
    return ReadResult(cols=cols, mask=mask,
                      rows_read_frac=jnp.ones((len(ids),)),
                      bytes_read=int(raw.size))


def collect(result: ReadResult) -> dict[str, np.ndarray]:
    """Materialize qualifying rows (host side, for tests/examples)."""
    m = np.asarray(result.mask).reshape(-1)
    return {c: np.asarray(v).reshape(-1)[m] for c, v in result.cols.items()}
