"""HAIL query pipeline (paper §4): annotations, replica planning, record
readers (index scan vs full scan), PAX->row reconstruction.

Replica selection mirrors §4.3: for each block, prefer an *alive* replica
whose clustered index matches the filter attribute; otherwise fall back to
any alive replica with a full scan (failover path — Fig 8's experiment).

Record readers are jit'd, *batched over many blocks per call* — that batching
is exactly what HailSplitting enables (one dispatch per split instead of one
per block); the benchmarks measure both policies.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as idx
from repro.core import parse as ps
from repro.core.schema import ROWID, Schema
from repro.core.store import BlockStore


@dataclasses.dataclass(frozen=True)
class HailQuery:
    """filter: (column, lo, hi) inclusive range (point = lo==hi)."""
    filter: Optional[tuple[str, int, int]]
    projection: tuple[str, ...]

    @property
    def filter_col(self) -> Optional[str]:
        return self.filter[0] if self.filter else None


def hail_annotation(schema: Schema, filter: str = "", projection: str = ""):
    """Parse the paper's @HailQuery annotation syntax:

      @HailQuery(filter="@3 between(7305,7670)", projection={@1})
      filter forms: "@k between(a,b)" | "@k = v"   (@k is 1-based position)
    """
    flt = None
    if filter:
        m = re.match(r"@(\d+)\s+between\((-?\d+),\s*(-?\d+)\)", filter.strip())
        if m:
            col = schema.columns[int(m.group(1)) - 1].name
            flt = (col, int(m.group(2)), int(m.group(3)))
        else:
            m = re.match(r"@(\d+)\s*=\s*(-?\d+)", filter.strip())
            if not m:
                raise ValueError(f"bad filter annotation: {filter!r}")
            col = schema.columns[int(m.group(1)) - 1].name
            v = int(m.group(2))
            flt = (col, v, v)
    proj = tuple(schema.columns[int(p) - 1].name
                 for p in re.findall(r"@(\d+)", projection))
    return HailQuery(filter=flt, projection=proj or schema.names)


def hail_query(filter: str = "", projection: str = "", schema: Schema = None):
    """Decorator flavour: @hail_query(filter=..., projection=...) on a map fn."""
    def deco(fn):
        fn.__hail_query__ = hail_annotation(schema, filter, projection)
        return fn
    return deco


# ---------------------------------------------------------------------------
# Planning (the JobClient/JobTracker side)
# ---------------------------------------------------------------------------

FULL_SCAN = -1


@dataclasses.dataclass
class QueryPlan:
    replica_for_block: np.ndarray    # (n_blocks,) replica idx used for reading
    index_scan: np.ndarray           # (n_blocks,) bool: index scan possible
    nodes: np.ndarray                # (n_blocks,) datanode serving the read


def plan(store: BlockStore, query: HailQuery) -> QueryPlan:
    nb = store.n_blocks
    rep = np.zeros(nb, dtype=np.int64)
    is_idx = np.zeros(nb, dtype=bool)
    nodes = np.zeros(nb, dtype=np.int64)
    want = query.filter_col
    for b in range(nb):
        alive = store.alive_replica_ids(b)
        if not alive:
            raise RuntimeError(f"block {b}: all replicas lost")
        choice = None
        if want is not None and store.layout == "pax":
            for i in alive:
                if store.replicas[i].sort_key == want:
                    choice = i
                    is_idx[b] = True
                    break
        if choice is None:
            choice = alive[0]
        rep[b] = choice
        nodes[b] = int(store.replicas[choice].nodes[b])
    return QueryPlan(replica_for_block=rep, index_scan=is_idx, nodes=nodes)


# ---------------------------------------------------------------------------
# Record readers (jit'd, batched over blocks)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("partition_size", "lo", "hi"))
def _index_read(sorted_key, mins, bad, *, partition_size: int, lo: int, hi: int):
    f = jax.vmap(lambda k, m, b: idx.index_scan_mask(k, m, lo, hi,
                                                     partition_size) & ~b)
    mask = f(sorted_key, mins, bad)
    g = jax.vmap(lambda m: idx.rows_read_fraction(m, lo, hi, partition_size,
                                                  sorted_key.shape[1]))
    return mask, g(mins)


@functools.partial(jax.jit, static_argnames=("lo", "hi"))
def _full_read(key_col, bad, *, lo: int, hi: int):
    return jax.vmap(lambda k, b: idx.full_scan_mask(k, lo, hi) & ~b)(key_col, bad)


@dataclasses.dataclass
class ReadResult:
    """Fixed-shape result: projected columns + qualifying mask."""
    cols: dict[str, jax.Array]     # col -> (n_blocks, rows)
    mask: jax.Array                # (n_blocks, rows) bool
    rows_read_frac: jax.Array      # (n_blocks,) I/O model input
    bytes_read: int                # modeled bytes (index scan reads less)


def _bad_mask(store: BlockStore, replica: int) -> jax.Array:
    """Bad rows sit at the tail of indexed replicas (sorted there); for an
    unindexed PAX replica they stay at their original upload positions."""
    if store.replicas[replica].sort_key is None:
        if store.bad_original is not None:
            return store.bad_original
        return jnp.zeros((store.n_blocks, store.rows_per_block), bool)
    r = jnp.arange(store.rows_per_block, dtype=jnp.int32)[None, :]
    return r >= (store.rows_per_block - store.bad_counts[:, None])


def read_hail(store: BlockStore, query: HailQuery, qplan: QueryPlan,
              block_ids: Sequence[int] | None = None) -> ReadResult:
    """HAIL record reader over (a subset of) blocks, per-replica batched."""
    nb = store.n_blocks
    ids = np.arange(nb) if block_ids is None else np.asarray(block_ids)
    rows = store.rows_per_block
    mask = jnp.zeros((len(ids), rows), bool)
    frac = jnp.ones((len(ids),), jnp.float32)
    out_cols = {c: jnp.zeros((len(ids), rows), store.replicas[0].cols[c].dtype)
                for c in query.projection + (ROWID,)}
    col_bytes = 4 * rows
    bytes_read = 0
    for rid in np.unique(qplan.replica_for_block[ids]):
        sel = np.nonzero(qplan.replica_for_block[ids] == rid)[0]
        bsel = ids[sel]
        rep = store.replicas[int(rid)]
        bad = _bad_mask(store, int(rid))[bsel]
        use_index = bool(qplan.index_scan[bsel].all()) and query.filter is not None
        if query.filter is not None:
            col, lo, hi = query.filter
            if use_index:
                m, fr = _index_read(rep.cols[col][bsel], rep.mins[bsel], bad,
                                    partition_size=store.partition_size,
                                    lo=lo, hi=hi)
                frac = frac.at[sel].set(fr.astype(jnp.float32))
            else:
                m = _full_read(rep.cols[col][bsel], bad, lo=lo, hi=hi)
                fr = jnp.ones((len(bsel),))
            mask = mask.at[sel].set(m)
        else:
            m = ~bad
            fr = jnp.ones((len(bsel),))
            mask = mask.at[sel].set(m)
        # modeled I/O: filter column read per partition range; projected
        # columns read for qualifying partitions only (PAX pruning)
        bytes_read += int(np.asarray(fr).sum() * col_bytes
                          * (1 + len(query.projection)))
        for c in query.projection + (ROWID,):
            out_cols[c] = out_cols[c].at[sel].set(rep.cols[c][bsel])
    return ReadResult(cols=out_cols, mask=mask, rows_read_frac=frac,
                      bytes_read=bytes_read)


def read_hail_kernels(store: BlockStore, query: HailQuery, qplan: QueryPlan,
                      block_ids: Sequence[int] | None = None) -> ReadResult:
    """Kernel-backed record reader: index_search + pax_scan Pallas kernels
    (interpret mode on CPU).  Semantics identical to read_hail — asserted by
    tests/test_kernels.py::test_record_reader_kernel_equivalence."""
    from repro.kernels import ops

    assert query.filter is not None and store.layout == "pax"
    col, lo, hi = query.filter
    ids = (np.arange(store.n_blocks) if block_ids is None
           else np.asarray(block_ids))
    rows = store.rows_per_block
    rid0 = int(qplan.replica_for_block[ids[0]])
    assert all(int(qplan.replica_for_block[b]) == rid0 for b in ids), \
        "kernel reader expects a single-replica split"
    rep = store.replicas[rid0]
    use_index = bool(qplan.index_scan[ids].all())
    proj_cols = tuple(query.projection) + (ROWID,)

    keys = rep.cols[col][ids]
    proj = jnp.stack([rep.cols[c][ids] for c in proj_cols], axis=-1)
    bad = np.asarray(_bad_mask(store, rid0))[ids]

    if use_index:
        pr = np.asarray(ops.index_search(rep.mins[ids], lo, hi))
    masks, outs, fracs = [], [], []
    for i, b in enumerate(ids):
        if use_index:
            r0 = int(pr[i, 0]) * store.partition_size
            r1 = min((int(pr[i, 1]) + 1) * store.partition_size, rows)
        else:
            r0, r1 = 0, rows
        m, o, _ = ops.pax_scan(keys[i, r0:r1], proj[i, r0:r1], lo, hi)
        full_m = jnp.zeros((rows,), bool).at[r0:r1].set(m)
        full_o = jnp.zeros((rows, len(proj_cols)), proj.dtype).at[r0:r1].set(o)
        masks.append(full_m & ~bad[i])
        outs.append(full_o)
        fracs.append((r1 - r0) / rows)
    mask = jnp.stack(masks)
    out = jnp.stack(outs)
    cols = {c: out[..., j] for j, c in enumerate(proj_cols)}
    col_bytes = 4 * rows
    return ReadResult(cols=cols, mask=mask,
                      rows_read_frac=jnp.asarray(fracs, jnp.float32),
                      bytes_read=int(sum(fracs) * col_bytes
                                     * (1 + len(query.projection))))


def read_hadoop(store: BlockStore, query: HailQuery,
                block_ids: Sequence[int] | None = None) -> ReadResult:
    """Hadoop baseline: parse raw ASCII rows, then scan (row layout)."""
    assert store.layout == "row_ascii"
    ids = (np.arange(store.n_blocks) if block_ids is None
           else np.asarray(block_ids))
    raw = store.replicas[0].cols["__raw__"][ids]

    @jax.jit
    def go(raw, bids):
        def one(block, bid):
            cols, bad = ps.parse_block(store.schema, block)
            cols[ROWID] = (bid * block.shape[0]
                           + jnp.arange(block.shape[0], dtype=jnp.int32))
            if query.filter is not None:
                col, lo, hi = query.filter
                m = idx.full_scan_mask(cols[col], lo, hi) & ~bad
            else:
                m = ~bad
            return {c: cols[c] for c in query.projection + (ROWID,)}, m

        return jax.vmap(one)(raw, bids)

    cols, mask = go(raw, jnp.asarray(ids, jnp.int32))
    return ReadResult(cols=cols, mask=mask,
                      rows_read_frac=jnp.ones((len(ids),)),
                      bytes_read=int(raw.size))


def collect(result: ReadResult) -> dict[str, np.ndarray]:
    """Materialize qualifying rows (host side, for tests/examples)."""
    m = np.asarray(result.mask).reshape(-1)
    return {c: np.asarray(v).reshape(-1)[m] for c, v in result.cols.items()}
