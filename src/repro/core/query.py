"""HAIL query pipeline (paper §4): annotations, replica planning, record
readers (index scan vs full scan), PAX->row reconstruction.

Replica selection mirrors §4.3: for each block, prefer an *alive* replica
whose clustered index matches the filter attribute; otherwise fall back to
any alive replica with a full scan (failover path — Fig 8's experiment).

Record readers are jit'd, *batched over many blocks per call* — that batching
is exactly what HailSplitting enables (ONE dispatch per split instead of one
per block); the benchmarks measure both policies.  Two properties keep the
hot path dispatch- and compile-free:

* (lo, hi) are TRACED arguments everywhere (SMEM runtime scalars for the
  Pallas readers, ordinary traced scalars for the jnp readers), so a
  compiled reader is reused across every query against the same store
  shape — zero per-query recompiles;
* ``read_hail_kernels`` issues exactly one fused ``hail_read`` pallas_call
  per split regardless of block count, including MIXED-replica and failover
  splits (per-block ``use_index`` flags select pruned index scan vs full
  scan inside the kernel).
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import governor as gov
from repro.core import index as idx
from repro.core import parse as ps
from repro.core.schema import ROWID, Schema
from repro.core.store import BlockStore


@dataclasses.dataclass(frozen=True)
class HailQuery:
    """filter: (column, lo, hi) inclusive range (point = lo==hi)."""
    filter: Optional[tuple[str, int, int]]
    projection: tuple[str, ...]

    @property
    def filter_col(self) -> Optional[str]:
        return self.filter[0] if self.filter else None


def hail_annotation(schema: Schema, filter: str = "", projection: str = ""):
    """Parse the paper's @HailQuery annotation syntax:

      @HailQuery(filter="@3 between(7305,7670)", projection={@1})
      filter forms: "@k between(a,b)" | "@k = v"   (@k is 1-based position)
    """
    flt = None
    if filter:
        m = re.match(r"@(\d+)\s+between\((-?\d+),\s*(-?\d+)\)", filter.strip())
        if m:
            col = schema.columns[int(m.group(1)) - 1].name
            flt = (col, int(m.group(2)), int(m.group(3)))
        else:
            m = re.match(r"@(\d+)\s*=\s*(-?\d+)", filter.strip())
            if not m:
                raise ValueError(f"bad filter annotation: {filter!r}")
            col = schema.columns[int(m.group(1)) - 1].name
            v = int(m.group(2))
            flt = (col, v, v)
    proj = tuple(schema.columns[int(p) - 1].name
                 for p in re.findall(r"@(\d+)", projection))
    return HailQuery(filter=flt, projection=proj or schema.names)


def hail_query(filter: str = "", projection: str = "", schema: Schema = None):
    """Decorator flavour: @hail_query(filter=..., projection=...) on a map fn."""
    def deco(fn):
        fn.__hail_query__ = hail_annotation(schema, filter, projection)
        return fn
    return deco


# ---------------------------------------------------------------------------
# Planning (the JobClient/JobTracker side)
# ---------------------------------------------------------------------------

FULL_SCAN = -1


@dataclasses.dataclass
class QueryPlan:
    replica_for_block: np.ndarray    # (n_blocks,) replica idx used for reading
    index_scan: np.ndarray           # (n_blocks,) bool: index scan possible
    nodes: np.ndarray                # (n_blocks,) datanode serving the read


def plan(store: BlockStore, query: HailQuery) -> QueryPlan:
    """Replica selection against the store's LIVE per-block index state.

    A replica qualifies a block for index scan only if its clustered index
    both matches the filter attribute AND has actually been built for that
    block (``Replica.block_indexed``) — under adaptive indexing blocks of
    the same replica flip from full scan to index scan as running jobs
    commit indexes, and re-planning picks that up job over job.
    """
    nb = store.n_blocks
    rep = np.zeros(nb, dtype=np.int64)
    is_idx = np.zeros(nb, dtype=bool)
    nodes = np.zeros(nb, dtype=np.int64)
    want = query.filter_col
    for b in range(nb):
        alive = store.alive_replica_ids(b)
        if not alive:
            raise RuntimeError(f"block {b}: all replicas lost")
        choice = None
        if want is not None and store.layout == "pax":
            for i in alive:
                if (store.replicas[i].sort_key == want
                        and store.replicas[i].block_indexed(b)):
                    choice = i
                    is_idx[b] = True
                    break
        if choice is None:
            choice = alive[0]
        rep[b] = choice
        nodes[b] = int(store.replicas[choice].nodes[b])
    return QueryPlan(replica_for_block=rep, index_scan=is_idx, nodes=nodes)


# ---------------------------------------------------------------------------
# Record readers (jit'd, batched over blocks)
# ---------------------------------------------------------------------------


# lo/hi are TRACED: ten different query ranges = one compilation.
@functools.partial(jax.jit, static_argnames=("partition_size",))
def _index_read(sorted_key, mins, bad, lo, hi, *, partition_size: int):
    f = jax.vmap(lambda k, m, b: idx.index_scan_mask(k, m, lo, hi,
                                                     partition_size) & ~b)
    mask = f(sorted_key, mins, bad)
    g = jax.vmap(lambda m: idx.rows_read_fraction(m, lo, hi, partition_size,
                                                  sorted_key.shape[1]))
    return mask, g(mins)


@jax.jit
def _full_read(key_col, bad, lo, hi):
    return jax.vmap(lambda k, b: idx.full_scan_mask(k, lo, hi) & ~b)(key_col, bad)


@dataclasses.dataclass
class ReadResult:
    """Fixed-shape result: projected columns + qualifying mask."""
    cols: dict[str, jax.Array]     # col -> (n_blocks, rows)
    mask: jax.Array                # (n_blocks, rows) bool
    rows_read_frac: jax.Array      # (n_blocks,) I/O model input
    bytes_read: "int | jax.Array"  # modeled bytes (index scan reads less);
    # may be a LAZY 0-d array so building a ReadResult never forces a
    # device sync — run_job materializes it at the completion barrier


def _bad_mask(store: BlockStore, replica: int) -> jax.Array:
    """Bad rows sit at the tail of INDEXED blocks (sorted there); for a
    block that is still unindexed they stay at their original upload
    positions — under adaptive indexing one replica mixes both, per block.
    Cached per (store, replica); ``commit_block_indexes`` invalidates the
    entry when a job flips blocks from upload order to sorted."""
    cache = store.__dict__.setdefault("_bad_mask_cache", {})
    if replica in cache:
        return cache[replica]
    rep = store.replicas[replica]
    orig = (store.bad_original if store.bad_original is not None
            else jnp.zeros((store.n_blocks, store.rows_per_block), bool))
    if rep.sort_key is None:
        m = orig
    else:
        r = jnp.arange(store.rows_per_block, dtype=jnp.int32)[None, :]
        tail = r >= (store.rows_per_block - store.bad_counts[:, None])
        if rep.indexed.all():
            m = tail
        else:
            m = jnp.where(jnp.asarray(rep.indexed)[:, None], tail, orig)
    cache[replica] = m
    return m


def read_hail(store: BlockStore, query: HailQuery, qplan: QueryPlan,
              block_ids: Sequence[int] | None = None) -> ReadResult:
    """HAIL record reader over (a subset of) blocks, per-replica batched.

    Assembly is GATHER-based: per-replica batches are concatenated in
    replica order and restored to input order with one inverse-permutation
    take per array — no per-group ``.at[sel].set`` scatters on the hot path.
    """
    nb = store.n_blocks
    ids = np.arange(nb) if block_ids is None else np.asarray(block_ids)
    rows = store.rows_per_block
    proj_cols = query.projection + (ROWID,)
    if len(ids) == 0:                # degenerate split: empty fixed-shape result
        return ReadResult(
            cols={c: jnp.zeros((0, rows), store.replicas[0].cols[c].dtype)
                  for c in proj_cols},
            mask=jnp.zeros((0, rows), bool),
            rows_read_frac=jnp.zeros((0,), jnp.float32), bytes_read=0)
    from repro.kernels import ops
    col_bytes = 4 * rows
    bytes_read = jnp.zeros((), jnp.float32)   # lazy: no sync at dispatch
    order: list[np.ndarray] = []     # input positions, concatenation order
    masks, fracs = [], []
    cols_parts: dict[str, list] = {c: [] for c in proj_cols}
    for rid in np.unique(qplan.replica_for_block[ids]):
        sel = np.nonzero(qplan.replica_for_block[ids] == rid)[0]
        bsel = ids[sel]
        rep = store.replicas[int(rid)]
        bad = _bad_mask(store, int(rid))[bsel]
        use_index = bool(qplan.index_scan[bsel].all()) and query.filter is not None
        if query.filter is not None:
            kind = "index_scan_blocks" if use_index else "full_scan_blocks"
            ops.DISPATCH_COUNTS[kind] += len(bsel)
            col, lo, hi = query.filter
            # per-column attribution: reader_stats + the store's AccessLog
            # (the governor's LRU eviction signal)
            gov.attribute_read(store, int(rid), col,
                               len(bsel) if use_index else 0,
                               0 if use_index else len(bsel))
            if use_index:
                m, fr = _index_read(rep.cols[col][bsel], rep.mins[bsel], bad,
                                    lo, hi,
                                    partition_size=store.partition_size)
                fr = fr.astype(jnp.float32)
            else:
                m = _full_read(rep.cols[col][bsel], bad, lo, hi)
                fr = jnp.ones((len(bsel),), jnp.float32)
        else:
            m = ~bad
            fr = jnp.ones((len(bsel),), jnp.float32)
        # modeled I/O: filter column read per partition range; projected
        # columns read for qualifying partitions only (PAX pruning)
        bytes_read += fr.sum() * col_bytes * (1 + len(query.projection))
        order.append(sel)
        masks.append(m)
        fracs.append(fr)
        for c in proj_cols:
            cols_parts[c].append(rep.cols[c][bsel])
    inv = np.empty(len(ids), dtype=np.int64)
    inv[np.concatenate(order)] = np.arange(len(ids))
    if len(order) == 1:              # single replica: concat+gather is a noop
        mask, frac = masks[0], fracs[0]
        out_cols = {c: v[0] for c, v in cols_parts.items()}
    else:
        mask = jnp.concatenate(masks, axis=0)[inv]
        frac = jnp.concatenate(fracs, axis=0)[inv]
        out_cols = {c: jnp.concatenate(v, axis=0)[inv]
                    for c, v in cols_parts.items()}
    return ReadResult(cols=out_cols, mask=mask, rows_read_frac=frac,
                      bytes_read=bytes_read)


def read_hail_kernels(store: BlockStore, query: HailQuery, qplan: QueryPlan,
                      block_ids: Sequence[int] | None = None) -> ReadResult:
    """Kernel-backed record reader: ONE fused ``hail_read`` pallas_call per
    split (interpret mode on CPU), regardless of block count or replica mix.

    The kernel reads each block's root directory, prunes row tiles outside
    the qualifying partition range (per-block ``use_index`` selects pruned
    index scan vs failover full scan), and masks bad rows — so mixed-replica
    splits and the per-block retry splits ``run_job`` re-plans after a node
    failure all go through the same single dispatch.  Semantics identical to
    read_hail — asserted end-to-end by tests/test_kernels.py and
    tests/test_fused_reader.py."""
    from repro.kernels import ops

    assert query.filter is not None and store.layout == "pax"
    col, lo, hi = query.filter
    ids = (np.arange(store.n_blocks) if block_ids is None
           else np.asarray(block_ids))
    rows = store.rows_per_block
    proj_cols = tuple(query.projection) + (ROWID,)
    if len(ids) == 0:                # degenerate split: empty fixed-shape result
        return ReadResult(
            cols={c: jnp.zeros((0, rows), store.replicas[0].cols[c].dtype)
                  for c in proj_cols},
            mask=jnp.zeros((0, rows), bool),
            rows_read_frac=jnp.zeros((0,), jnp.float32), bytes_read=0)
    rids = qplan.replica_for_block[ids]

    # Gather per-block inputs from each block's chosen replica (host-side
    # group + concat + inverse-permutation, same scheme as read_hail).
    order, keys_p, proj_p, bad_p, mins_p, uidx_p = [], [], [], [], [], []
    for rid in np.unique(rids):
        sel = np.nonzero(rids == rid)[0]
        bsel = ids[sel]
        rep = store.replicas[int(rid)]
        n_idx = int(np.asarray(qplan.index_scan[bsel], bool).sum())
        gov.attribute_read(store, int(rid), col, n_idx, len(bsel) - n_idx)
        order.append(sel)
        keys_p.append(rep.cols[col][bsel])
        proj_p.append(jnp.stack([rep.cols[c][bsel] for c in proj_cols],
                                axis=-1))
        bad_p.append(_bad_mask(store, int(rid))[bsel])
        mins_p.append(rep.mins[bsel])
        uidx_p.append(np.asarray(qplan.index_scan[bsel], np.int32))
    inv = np.empty(len(ids), dtype=np.int64)
    inv[np.concatenate(order)] = np.arange(len(ids))
    if len(order) == 1:
        keys, proj, bad = keys_p[0], proj_p[0], bad_p[0]
        mins, uidx = mins_p[0], uidx_p[0]
    else:
        keys = jnp.concatenate(keys_p, axis=0)[inv]
        proj = jnp.concatenate(proj_p, axis=0)[inv]
        bad = jnp.concatenate(bad_p, axis=0)[inv]
        mins = jnp.concatenate(mins_p, axis=0)[inv]
        uidx = np.concatenate(uidx_p, axis=0)[inv]

    # one dispatch for the whole split; lo/hi are runtime scalars; uidx
    # stays a host array so ops' scan-mode counters cost no device sync
    mask, out, frac = ops.hail_read(mins, keys, proj, bad, uidx,
                                    lo, hi,
                                    partition_size=store.partition_size)
    cols = {c: out[..., j] for j, c in enumerate(proj_cols)}
    col_bytes = 4 * rows
    return ReadResult(cols=cols, mask=mask, rows_read_frac=frac,
                      bytes_read=frac.sum() * col_bytes
                      * (1 + len(query.projection)))


@functools.lru_cache(maxsize=None)
def _hadoop_reader(schema, filter_col, projection):
    """Compiled parse+scan for (schema, filter col, projection) — (lo, hi)
    and the data are traced, so the parser compiles once per job SHAPE, not
    once per split per query (the seed rebuilt the jit closure per call)."""

    @jax.jit
    def go(raw, bids, lo, hi):
        def one(block, bid):
            cols, bad = ps.parse_block(schema, block)
            cols[ROWID] = (bid * block.shape[0]
                           + jnp.arange(block.shape[0], dtype=jnp.int32))
            if filter_col is not None:
                m = idx.full_scan_mask(cols[filter_col], lo, hi) & ~bad
            else:
                m = ~bad
            return {c: cols[c] for c in projection + (ROWID,)}, m

        return jax.vmap(one)(raw, bids)

    return go


def read_hadoop(store: BlockStore, query: HailQuery,
                block_ids: Sequence[int] | None = None) -> ReadResult:
    """Hadoop baseline: parse raw ASCII rows, then scan (row layout)."""
    assert store.layout == "row_ascii"
    ids = (np.arange(store.n_blocks) if block_ids is None
           else np.asarray(block_ids))
    raw = store.replicas[0].cols["__raw__"][ids]

    go = _hadoop_reader(store.schema, query.filter_col, query.projection)
    if query.filter is not None:
        _, lo, hi = query.filter
    else:
        lo = hi = 0
    cols, mask = go(raw, jnp.asarray(ids, jnp.int32),
                    jnp.asarray(lo, jnp.int32), jnp.asarray(hi, jnp.int32))
    return ReadResult(cols=cols, mask=mask,
                      rows_read_frac=jnp.ones((len(ids),)),
                      bytes_read=int(raw.size))


def collect(result: ReadResult) -> dict[str, np.ndarray]:
    """Materialize qualifying rows (host side, for tests/examples)."""
    m = np.asarray(result.mask).reshape(-1)
    return {c: np.asarray(v).reshape(-1)[m] for c, v in result.cols.items()}
