"""Storage-budget index governor: LRU eviction + replica re-claiming.

HAIL's win-win assumes a fixed workload: once adaptive jobs (mapreduce's
LIAH path) have claimed every replica — one clustered index per replica —
a SHIFTED workload can never earn an index and degrades to permanent full
scans; and without a storage budget the indexed footprint only ever grows.
The governor closes that loop:

* ``AccessLog`` — persistent per-(replica, filter-column) hit/miss counters
  on the ``BlockStore``, fed by the record readers (``query.read_hail`` /
  ``read_hail_kernels`` call ``note_read``; the same attribution also lands
  in ``kernels.ops`` ``reader_stats`` as ``index_scan_blocks[col]`` /
  ``full_scan_blocks[col]`` counters).  A logical clock stamps every read,
  so recency is workload-defined, not wall-clock-defined.

* ``GovernorConfig`` — a storage budget: ``max_indexed_blocks`` and/or
  ``max_indexed_bytes`` bound the TOTAL per-block indexes held across all
  replicas.  Enforced both proactively (``run_job`` trims build offers and
  demotes victims to make room) and as a hard backstop at
  ``BlockStore.commit_block_indexes`` time, so the budget can never be
  exceeded no matter who commits.

* ``IndexGovernor.may_reclaim`` — claim-time eviction HYSTERESIS: a shifted
  workload must show misses in >= ``claim_miss_jobs`` distinct jobs (the
  requesting job included) before a claim-time demotion fires, so a
  workload that queries once never destroys a warm index.  The job
  boundaries come from ``AccessLog.begin_job`` (``note_job_start``), bumped
  by every ``run_job`` and once per HailServer FLUSH (the user-visible
  workload unit — per-batch boundaries would let one flush's own batches
  satisfy the threshold).

* ``IndexGovernor.victim`` — the LRU/hit-rate policy: among replicas whose
  clustered index does NOT serve the protected (current) filter columns,
  pick the one whose (replica, sort_key) record is least recently used,
  breaking ties toward fewer lifetime hits, then lower replica id.  The
  chosen replica is DEMOTED (``BlockStore.demote_replica``): its per-block
  indexes drop back to ``sort_key=None`` upload order, the namenode's
  Dir_rep rewinds, and the replica becomes re-claimable by the shifted
  workload through the ordinary adaptive claim/commit path — so a workload
  shift reconverges in ~``ceil(1/offer_rate)`` jobs (EXPERIMENTS.md).

The governor itself never sorts or reads data; it only decides.  The
destructive work lives in ``store.demote_replica`` so every store invariant
(checksums, bad-mask coherence, Dir_rep) is maintained in one place.
"""
from __future__ import annotations

import dataclasses
import re
from typing import TYPE_CHECKING, Any, Optional, Sequence

if TYPE_CHECKING:  # import cycle guard: store never imports governor
    from repro.core.store import BlockStore


@dataclasses.dataclass
class AccessRecord:
    """Hit/miss counters for one (replica, filter-column) pair."""
    hits: int = 0        # blocks served by an index scan
    misses: int = 0      # blocks that had to full-scan
    last_used: int = 0   # AccessLog clock value of the most recent read


class AccessLog:
    """Per-store read-attribution log (persistent across jobs).

    ``record`` is called by the record readers once per (replica, column)
    batch; the logical ``clock`` advances per call so "recently used" means
    "recently queried", independent of wall time.

    A coarser JOB clock (``begin_job``, bumped once per run_job / server
    flush) groups reads into jobs: ``miss_jobs`` remembers, per filter
    column, WHICH distinct jobs had to full-scan for it.  That powers the
    governor's claim-time eviction hysteresis — one job of misses is a
    probe, repeated jobs are a workload.  Demotion forgets a replica's
    (replica, column) records but NOT ``miss_jobs``: the evidence that a
    column's workload keeps coming back is column-level, not replica-level.
    """

    def __init__(self):
        self.clock = 0
        self.job_clock = 0
        self.counts: dict[tuple[int, str], AccessRecord] = {}
        self.miss_jobs: dict[str, set[int]] = {}

    def begin_job(self) -> int:
        """Advance the job clock (one executor job / one server flush)."""
        self.job_clock += 1
        return self.job_clock

    def record(self, replica_id: int, col: str, n_index: int, n_full: int):
        self.clock += 1
        rec = self.counts.setdefault((replica_id, col), AccessRecord())
        rec.hits += int(n_index)
        rec.misses += int(n_full)
        rec.last_used = self.clock
        if n_full > 0:
            self.miss_jobs.setdefault(col, set()).add(self.job_clock)

    def distinct_miss_jobs(self, col: str,
                           exclude_current: bool = False) -> int:
        """How many distinct jobs have full-scanned for ``col`` so far.

        ``exclude_current`` drops the job the clock currently points at —
        the hysteresis gate counts the requesting job separately, and by
        the time a server flush's second batch asks, the first batch's
        misses have already landed under the SAME job id."""
        jobs = self.miss_jobs.get(col, set())
        if exclude_current:
            return len(jobs - {self.job_clock})
        return len(jobs)

    def get(self, replica_id: int, col: str) -> Optional[AccessRecord]:
        return self.counts.get((replica_id, col))

    def heat(self, replica_id: int, col: str) -> int:
        """Lifetime read demand (hits + misses) for one (replica, column)
        — the BlockCache's admission tie-break: the same frequency data
        the governor's eviction policy reads, so cache admission and
        index eviction agree on what "hot" means."""
        rec = self.counts.get((replica_id, col))
        return (rec.hits + rec.misses) if rec is not None else 0

    def col_totals(self, col: str) -> AccessRecord:
        """Aggregate over replicas (convergence dashboards / tests)."""
        out = AccessRecord()
        for (rid, c), rec in self.counts.items():
            if c == col:
                out.hits += rec.hits
                out.misses += rec.misses
                out.last_used = max(out.last_used, rec.last_used)
        return out

    def forget_replica(self, replica_id: int):
        """Demotion rewinds a replica's history — a re-claimed replica
        starts cold instead of inheriting the old workload's recency."""
        for key in [k for k in self.counts if k[0] == replica_id]:
            del self.counts[key]


def note_read(store: "BlockStore", replica_id: int, col: str,
              n_index: int, n_full: int):
    """Attribute one batch of block reads to the store's ``AccessLog``.

    Creates the log lazily so ungoverned stores pay one dict lookup and
    stay otherwise untouched.
    """
    log = store.access_log
    if log is None:
        log = store.access_log = AccessLog()
    log.record(replica_id, col, n_index, n_full)


def attribute_read(store: "BlockStore", replica_id: int, col: str,
                   n_index: int, n_full: int):
    """Record-reader hook: ONE source of truth for per-column attribution.

    Bumps the ``reader_stats`` per-column counters
    (``index_scan_blocks[col]`` / ``full_scan_blocks[col]`` in
    ``kernels.ops``) and feeds the same numbers into the ``AccessLog`` —
    both record readers call this so the jnp and fused-kernel paths can
    never drift apart on the governor's eviction signal.
    """
    from repro.kernels import ops
    ops.DISPATCH_COUNTS[f"index_scan_blocks[{col}]"] += int(n_index)
    ops.DISPATCH_COUNTS[f"full_scan_blocks[{col}]"] += int(n_full)
    note_read(store, replica_id, col, n_index, n_full)


def note_job_start(store: "BlockStore") -> int:
    """Advance the store's job clock (creating the log lazily) — called at
    the top of every ``run_job`` and once per HailServer flush, so the
    hysteresis counter ``distinct_miss_jobs`` means what it says."""
    log = store.access_log
    if log is None:
        log = store.access_log = AccessLog()
    return log.begin_job()


def note_commit(store: "BlockStore", replica_id: int, col: str):
    """Commit-time recency stamp: a freshly built index counts as "just
    used" even before its first read.  Without this a zero-read new index
    scores (last_used=0, hits=0) — the coldest possible victim — and the
    next workload shift would thrash the index it just paid to build."""
    note_read(store, replica_id, col, 0, 0)


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    """Storage budget for per-block clustered indexes (whole store).

    ``max_indexed_blocks``: cap on the total number of indexed blocks summed
    over ALL replicas.  ``max_indexed_bytes``: same cap expressed in bytes
    (converted via the per-block PAX footprint).  Both ``None`` = unlimited
    (the governor still tracks demotions but never evicts for space).

    ``claim_miss_jobs``: eviction hysteresis for the CLAIM-TIME demotion
    path (every replica keyed elsewhere, a shifted workload wants one).
    Demotion requires at least this many distinct jobs of misses on the
    requesting column — the requesting job itself counts as one, so the
    default of 2 means a column's FIRST-ever job never destroys a warm
    index; the second distinct job does.  Budget-pressure eviction (the
    offer doesn't fit) is not hysteresis-gated: there the alternative is
    violating the storage budget, not merely scanning.
    """
    max_indexed_blocks: Optional[int] = None
    max_indexed_bytes: Optional[int] = None
    claim_miss_jobs: int = 2


@dataclasses.dataclass(frozen=True)
class DemotionEvent:
    replica_id: int
    sort_key: str
    blocks_dropped: int


class IndexGovernor:
    """Budget enforcement + LRU victim policy.  Pure decision logic — the
    destructive transition is ``BlockStore.demote_replica``."""

    def __init__(self, config: GovernorConfig):
        self.config = config
        self.events: list[DemotionEvent] = []

    # -- budget accounting --------------------------------------------------

    def budget_blocks(self, store: "BlockStore") -> float:
        limits = []
        if self.config.max_indexed_blocks is not None:
            limits.append(float(self.config.max_indexed_blocks))
        if self.config.max_indexed_bytes is not None:
            per_block = max(
                store.template_replica().nbytes // store.n_blocks, 1)
            limits.append(float(self.config.max_indexed_bytes // per_block))
        return min(limits) if limits else float("inf")

    def room(self, store: "BlockStore") -> float:
        """Indexed blocks the budget still allows (may be negative if the
        store was over budget when the governor was installed)."""
        return self.budget_blocks(store) - store.total_indexed_blocks()

    def admit(self, store: "BlockStore", replica_id: int, n_blocks: int) -> int:
        """Hard backstop at commit time: how many of ``n_blocks`` new
        per-block indexes fit.  Never demotes — eviction is a scheduled
        (run_job) decision, admission is an invariant."""
        room = self.room(store)
        if room == float("inf"):
            return n_blocks
        return max(0, min(n_blocks, int(room)))

    # -- eviction policy ----------------------------------------------------

    def victim(self, store: "BlockStore",
               protect: Sequence[str] = ()) -> Optional[int]:
        """LRU victim replica, or None when nothing is evictable.

        Candidates: replicas holding at least one per-block index whose
        ``sort_key`` is not protected (the current workload's filter columns
        are protected so a job never evicts the index it is converging on).
        Ranked by the access log's (replica, sort_key) record: least
        recently used first, then fewest lifetime hits, then replica id —
        replicas never queried since the log began sort first.
        """
        log = store.access_log
        best, best_score = None, None
        for i, rep in enumerate(store.replicas):
            if rep.retired or rep.sort_key is None or rep.sort_key in protect:
                continue
            if rep.indexed is None or not rep.indexed.any():
                continue
            rec = log.get(i, rep.sort_key) if log is not None else None
            score = ((rec.last_used if rec is not None else 0),
                     (rec.hits if rec is not None else 0), i)
            if best_score is None or score < best_score:
                best, best_score = i, score
        return best

    def may_reclaim(self, store: "BlockStore", col: str) -> bool:
        """Hysteresis gate for claim-time demotion on behalf of ``col``.

        True once ``col`` has accumulated misses in at least
        ``claim_miss_jobs`` distinct jobs, counting the requesting job
        (which is about to full-scan) as one — so a workload that queries
        once never evicts anything, while a recurring one waits exactly one
        extra job before re-claiming.  PRIOR jobs are counted excluding the
        job clock's current value: a flush's later batches must not pass
        the gate on misses their own flush just recorded.
        """
        log = store.access_log
        prior = (log.distinct_miss_jobs(col, exclude_current=True)
                 if log is not None else 0)
        return prior + 1 >= self.config.claim_miss_jobs

    def note_demotion(self, replica_id: int, sort_key: str,
                      blocks_dropped: int):
        self.events.append(DemotionEvent(replica_id, sort_key,
                                         blocks_dropped))
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        obs_metrics.REGISTRY.inc("governor.demotion_events", 1,
                                 replica=replica_id, column=sort_key)
        obs_metrics.REGISTRY.inc("governor.demoted_blocks", blocks_dropped,
                                 replica=replica_id, column=sort_key)
        obs_trace.instant("demotion", track="governor",
                          args={"replica": replica_id, "column": sort_key,
                                "blocks": blocks_dropped})

    @property
    def blocks_demoted_total(self) -> int:
        return sum(e.blocks_dropped for e in self.events)


def govern(store: "BlockStore", *,
           max_indexed_blocks: Optional[int] = None,
           max_indexed_bytes: Optional[int] = None,
           claim_miss_jobs: int = 2) -> IndexGovernor:
    """Attach a budget governor to a store (the one-call entry point)."""
    gov = IndexGovernor(GovernorConfig(max_indexed_blocks=max_indexed_blocks,
                                       max_indexed_bytes=max_indexed_bytes,
                                       claim_miss_jobs=claim_miss_jobs))
    store.governor = gov
    return gov


# ---------------------------------------------------------------------------
# Dynamic replication: replica COUNT follows measured heat
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplicationConfig:
    """Heat → replica-count policy (replaces the static factor-of-3).

    Scale UP: a filter column whose reads keep MISSING (full-scanning)
    while no replica is claimable for it (every live replica already keyed
    elsewhere) gets a fresh replica once its per-tick miss heat reaches
    ``hot_misses`` — the next adaptive job claims the new replica for that
    column (HAIL: one clustered index per replica, so a replica is an
    index *slot*).  Scale DOWN: a live replica whose own read heat across
    ALL columns stays at zero for ``cold_ticks`` consecutive ticks is
    decommissioned.  ``min_replication``/``max_replication`` bound the
    live replica count; the last-healthy-copy safety is the store's own
    invariant (``decommission_replica`` refuses).  ``n_nodes``: cluster
    size for placement (inferred from live replicas when None).
    """
    min_replication: int = 2
    max_replication: int = 5
    hot_misses: int = 1
    cold_ticks: int = 2
    n_nodes: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ReplicationEvent:
    kind: str                      # 'add' | 'decommission'
    replica_id: int
    column: Optional[str]          # the hot column (adds only)
    tick: int


class ReplicationController:
    """Closes the replication loop from MEASURED heat.

    The controller owns no bespoke plumbing into the read path: its inputs
    are ``registry.snapshot()`` DELTAS of the per-store collector's
    ``governor.heat{column=..,replica=..}`` / ``governor.miss_heat{..}``
    gauges (the AccessLog mirrored into the flight recorder), so anything
    the registry can see — cached reads replayed into the AccessLog
    included — moves the same controller.  ``run_job`` and
    ``HailServer.flush`` tick it at job/flush boundaries, like the
    scrubber.  Decisions delegate to ``BlockStore.add_replica`` /
    ``decommission_replica``; this class only decides.
    """

    _HEAT = re.compile(r"^governor\.(?P<kind>heat|miss_heat)"
                       r"\{column=(?P<col>[^,}]+),replica=(?P<rid>\d+)\}$")

    def __init__(self, store: "BlockStore",
                 config: ReplicationConfig = ReplicationConfig(),
                 registry: Any = None):
        from repro.obs import metrics as obs_metrics
        self.store = store
        self.config = config
        self.registry = (registry if registry is not None
                         else obs_metrics.REGISTRY)
        self._collector = obs_metrics.register_store(store, self.registry)
        self.events: list[ReplicationEvent] = []
        self.ticks = 0
        self._cold_streak: dict[int, int] = {}
        self._prev = self.registry.snapshot()

    def detach(self):
        """Unregister the store collector (store is done)."""
        self.registry.unregister_collector(self._collector)
        if self.store.replicator is self:
            self.store.replicator = None

    @property
    def replicas_added(self) -> int:
        return sum(e.kind == "add" for e in self.events)

    @property
    def replicas_decommissioned(self) -> int:
        return sum(e.kind == "decommission" for e in self.events)

    def _interval_heat(self) -> tuple[dict, dict]:
        """(total heat, miss heat) per (replica, column) since last tick,
        parsed from the registry's snapshot delta."""
        snap = self.registry.snapshot()
        d = self.registry.delta(self._prev, after=snap)
        self._prev = snap
        heat: dict[tuple[int, str], float] = {}
        miss: dict[tuple[int, str], float] = {}
        for series, v in d.items():
            m = self._HEAT.match(series)
            if m is None:
                continue
            key = (int(m.group("rid")), m.group("col"))
            (heat if m.group("kind") == "heat" else miss)[key] = v
        return heat, miss

    def tick(self) -> list[ReplicationEvent]:
        """One control quantum at a job/flush boundary."""
        self.ticks += 1
        heat, miss = self._interval_heat()
        added = self._scale_up(miss)
        out = added + self._scale_down(
            heat, protect={e.replica_id for e in added})
        self.events.extend(out)
        return out

    def _scale_up(self, miss: dict) -> list[ReplicationEvent]:
        store, cfg = self.store, self.config
        col_miss: dict[str, float] = {}
        for (rid, col), v in miss.items():
            col_miss[col] = col_miss.get(col, 0.0) + v
        out = []
        for col, v in sorted(col_miss.items(), key=lambda kv: -kv[1]):
            if v < cfg.hot_misses:
                break
            if len(store.live_replica_ids()) >= cfg.max_replication:
                break
            if store.adaptive_replica_for(col) is not None:
                continue     # keyed or claimable replica already serves it
            try:
                rid = store.add_replica(n_nodes=cfg.n_nodes)
            except ValueError:
                break        # cluster/healthy-copy limits: nothing to do
            self._cold_streak[rid] = 0
            self.registry.inc("replication.replicas_added", 1, column=col)
            from repro.obs import trace as obs_trace
            obs_trace.instant("replicate", track="governor",
                              args={"replica": rid, "column": col,
                                    "miss_heat": v})
            out.append(ReplicationEvent("add", rid, col, self.ticks))
        return out

    def _scale_down(self, heat: dict,
                    protect: set = frozenset()) -> list[ReplicationEvent]:
        store, cfg = self.store, self.config
        rid_heat: dict[int, float] = {}
        for (rid, col), v in heat.items():
            rid_heat[rid] = rid_heat.get(rid, 0.0) + v
        for rid in store.live_replica_ids():
            if rid_heat.get(rid, 0.0) > 0 or rid in protect:
                self._cold_streak[rid] = 0    # just-added replicas are warm
            else:
                self._cold_streak[rid] = self._cold_streak.get(rid, 0) + 1
        out = []
        # longest cold streak first; ties toward the youngest replica
        for rid in sorted(store.live_replica_ids(),
                          key=lambda i: (-self._cold_streak.get(i, 0), -i)):
            if len(store.live_replica_ids()) <= cfg.min_replication:
                break
            if self._cold_streak.get(rid, 0) < cfg.cold_ticks:
                continue
            try:
                dropped = store.decommission_replica(rid)
            except ValueError:
                continue     # would strand a block's last healthy copy
            self._cold_streak.pop(rid, None)
            self.registry.inc("replication.replicas_decommissioned", 1)
            from repro.obs import trace as obs_trace
            obs_trace.instant("decommission", track="governor",
                              args={"replica": rid,
                                    "indexes_dropped": dropped})
            out.append(ReplicationEvent("decommission", rid, None,
                                        self.ticks))
        return out


def replicate(store: "BlockStore", *,
              min_replication: int = 2, max_replication: int = 5,
              hot_misses: int = 1, cold_ticks: int = 2,
              n_nodes: Optional[int] = None,
              registry: Any = None) -> ReplicationController:
    """Attach a heat-driven replication controller (one-call entry point).
    ``run_job``/``HailServer.flush`` tick ``store.replicator`` at their
    job/flush boundaries."""
    ctl = ReplicationController(
        store,
        ReplicationConfig(min_replication=min_replication,
                          max_replication=max_replication,
                          hot_misses=hot_misses, cold_ticks=cold_ticks,
                          n_nodes=n_nodes),
        registry=registry)
    store.replicator = ctl
    return ctl
