"""Governor-integrated hot-block cache for the serving layer.

"Overview of Caching Mechanisms to Improve Hadoop Performance" makes the
case that INTER-JOB block caching is the dominant lever once the same data
is read by many jobs — exactly the HailServer's regime, where concurrent
tenants hammer the same hot replicas.  The unit cached here is the decoded
per-split device input the record readers otherwise rebuild on every call:
for one (replica, block-subset, filter column, projection) group, the
gathered key column, the stacked projection columns, the bad-row mask and
the root directories (``query._gather_replica_inputs``).  That is the
repro's analogue of a datanode's hot-block page cache: the host-side
gather + stack + device transfer is the per-read cost the cache removes,
while the fused reader's dispatch count stays one per (split, batch).

Policy and coherence:

* capacity-bounded LRU (``capacity_bytes``) — entries are touched on hit,
  evicted coldest-first when a put overflows the budget;
* the cache is INVALIDATED by the store's destructive transitions:
  ``BlockStore.commit_block_indexes`` and ``BlockStore.demote_replica``
  drop every entry of the touched replica (its columns, checksums, root
  directory and bad-mask layout all just changed), so a cached read can
  never observe a half-committed replica;
* cache traffic is still GOVERNED traffic: the record readers attribute
  every read — hit or miss — through ``governor.attribute_read`` into the
  store's ``AccessLog``, so the IndexGovernor's LRU eviction signal sees
  cached reads exactly like uncached ones (a hot-but-cached index must not
  look cold to the governor).  Hit/miss counts additionally land in
  ``kernels.ops`` ``reader_stats`` (``cache_hits`` / ``cache_misses``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Hashable, Optional


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0          # entries dropped for capacity
    invalidations: int = 0      # entries dropped by store transitions
    bytes_cached: int = 0       # current resident bytes
    peak_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def _nbytes(value: Any) -> int:
    """Total device bytes of a pytree-ish tuple/dict of arrays."""
    if isinstance(value, dict):
        return sum(_nbytes(v) for v in value.values())
    if isinstance(value, (tuple, list)):
        return sum(_nbytes(v) for v in value)
    size = getattr(value, "size", None)
    itemsize = getattr(getattr(value, "dtype", None), "itemsize", None)
    return int(size * itemsize) if size is not None and itemsize else 0


class BlockCache:
    """Capacity-bounded LRU over decoded per-split reader inputs.

    Keys are ``(replica_id, ...)`` tuples — the leading replica id is the
    invalidation handle for the store's destructive transitions.
    ``capacity_bytes=None`` means unbounded (cache everything)."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        self.capacity_bytes = capacity_bytes
        self._entries: "collections.OrderedDict[Hashable, tuple[Any, int]]" \
            = collections.OrderedDict()
        self.stats = CacheStats()

    def attach(self, store) -> "BlockCache":
        """Install on a ``BlockStore`` — the readers consult
        ``store.block_cache`` and the store invalidates on commit/demote."""
        store.block_cache = self
        return self

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: Hashable):
        """-> cached value or None; counts the hit/miss."""
        from repro.kernels import ops
        ent = self._entries.get(key)
        if ent is None:
            self.stats.misses += 1
            ops.DISPATCH_COUNTS["cache_misses"] += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        ops.DISPATCH_COUNTS["cache_hits"] += 1
        return ent[0]

    def put(self, key: Hashable, value: Any):
        nbytes = _nbytes(value)
        if self.capacity_bytes is not None and nbytes > self.capacity_bytes:
            return                       # larger than the whole budget
        old = self._entries.pop(key, None)
        if old is not None:
            self.stats.bytes_cached -= old[1]
        self._entries[key] = (value, nbytes)
        self.stats.bytes_cached += nbytes
        while (self.capacity_bytes is not None
               and self.stats.bytes_cached > self.capacity_bytes):
            _, (_, dropped) = self._entries.popitem(last=False)   # LRU out
            self.stats.bytes_cached -= dropped
            self.stats.evictions += 1
        self.stats.peak_bytes = max(self.stats.peak_bytes,
                                    self.stats.bytes_cached)

    def invalidate_replica(self, replica_id: int):
        """Drop every entry of one replica — called by the store's
        destructive transitions (index commit / demotion)."""
        stale = [k for k in self._entries if k[0] == replica_id]
        for k in stale:
            _, nbytes = self._entries.pop(k)
            self.stats.bytes_cached -= nbytes
            self.stats.invalidations += 1

    def invalidate_blocks(self, replica_id: int, block_ids):
        """Drop only the entries whose gathered block set intersects
        ``block_ids`` — quarantine/repair touch single blocks, so evicting
        the whole replica would throw away every hot split for one bad
        block.  Keys are ``(replica_id, block_tuple, ...)``."""
        bad = {int(b) for b in block_ids}
        stale = [k for k in self._entries
                 if k[0] == replica_id and bad.intersection(k[1])]
        for k in stale:
            _, nbytes = self._entries.pop(k)
            self.stats.bytes_cached -= nbytes
            self.stats.invalidations += 1

    def clear(self):
        self.stats.invalidations += len(self._entries)
        self._entries.clear()
        self.stats.bytes_cached = 0
