"""Tiered, governor-integrated caching for the serving layer.

"Overview of Caching Mechanisms to Improve Hadoop Performance" makes the
case that INTER-JOB block caching is the dominant lever once the same data
is read by many jobs — exactly the HailServer's regime, where concurrent
tenants hammer the same hot replicas.  Two tiers live here:

**Tier 1 — ``BlockCache``** holds the decoded per-split device input the
record readers otherwise rebuild on every call: for one (replica,
block-subset, filter column, projection) group, the gathered key column,
the stacked projection columns, the bad-row mask and the root directories
(``query._gather_replica_inputs``).  That is the repro's analogue of a
datanode's hot-block page cache: the host-side gather + stack + device
transfer is the per-read cost the cache removes, while the fused reader's
dispatch count stays one per (split, batch).

The policy is SCAN-RESISTANT, not pure LRU.  bench_server documented the
failure mode of the pure-LRU predecessor: sequential split access at a
half-working-set budget hit 0.0 with 186 evictions — every fill evicted a
block needed again before the admitted block was ever reused.  The fix is
SLRU segmentation plus TinyLFU-style admission:

* entries land in a PROBATION segment; a hit promotes them to a PROTECTED
  segment (bounded at ``protected_frac`` of capacity, its LRU overflow
  demoted back to probation) — one-touch entries can never displace
  entries that have proven reuse;
* when admitting a new entry would force evictions, the candidate must
  have a strictly HIGHER score than every would-be victim, else it is
  REJECTED (``stats.admission_rejects``) and the residents stay.  The
  score is (ghost frequency, governor column heat): a decayed per-key
  touch count that survives eviction, tie-broken by the store's
  ``AccessLog`` per-(replica, column) read totals — the same frequency
  data the IndexGovernor's eviction policy uses, so a one-touch
  sequential scan (frequency 1, cold column) can no longer flush blocks
  with demonstrated reuse.

**Tier 2 — ``ResultCache``** caches MATERIALIZED query answers keyed
``(filter col, lo, hi, projection, store version)``: a repeated range — or
one subsumed by a cached superset range, when the filter column is in the
projection — skips the fused scan entirely (zero dispatches).  Entries
carry an attribution recipe (per-replica index/full-scan block counts from
the fill-time read) that the server replays through
``governor.attribute_read`` on every hit, so a hot-but-result-cached index
never looks LRU-cold to the governor.

Coherence (both tiers): the store's DESTRUCTIVE transitions —
``commit_block_indexes``, ``demote_replica``, ``quarantine_block``,
``repair_blocks`` — invalidate them.  The BlockCache drops the touched
replica's entries (block-granular for quarantine/repair, with the
SURVIVING blocks of a partially hit entry re-keyed and re-accounted at
their true residual byte size); the ResultCache is dropped wholesale and
additionally keyed by ``BlockStore.version``, which those transitions
bump — a stale result is unreachable even if an invalidation hook is
bypassed.  Cache-owned buffers are MUTATION-PROOF: BlockCache values are
immutable ``jax.Array``s by construction, and ResultCache entries freeze
their numpy arrays at fill (``writeable=False``), so a caller scribbling on
a served answer raises instead of corrupting every future hit for that
key.  Cache traffic is still GOVERNED traffic: hits and misses land
in ``kernels.ops`` ``reader_stats`` (``cache_hits`` / ``cache_misses`` /
``result_cache_hits`` / ``result_cache_misses``), always attributed to the
innermost ``stats_scope``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Hashable, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0          # entries dropped for capacity
    admission_rejects: int = 0  # candidates refused by the scan filter
    invalidations: int = 0      # entries dropped by store transitions
    partial_invalidations: int = 0  # entries re-keyed to their residual
    promotions: int = 0         # probation -> protected (proven reuse)
    bytes_cached: int = 0       # current resident bytes
    peak_bytes: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


def _nbytes(value: Any) -> int:
    """Total device bytes of a pytree-ish tuple/dict of arrays."""
    if isinstance(value, dict):
        return sum(_nbytes(v) for v in value.values())
    if isinstance(value, (tuple, list)):
        return sum(_nbytes(v) for v in value)
    size = getattr(value, "size", None)
    itemsize = getattr(getattr(value, "dtype", None), "itemsize", None)
    return int(size * itemsize) if size is not None and itemsize else 0


def _slice_blocks(value: Any, keep: np.ndarray):
    """Take the ``keep`` positions along every array's leading (block)
    axis — used to shrink a cached gather to its surviving blocks after a
    block-granular invalidation."""
    if isinstance(value, dict):
        return {k: _slice_blocks(v, keep) for k, v in value.items()}
    if isinstance(value, (tuple, list)):
        return type(value)(_slice_blocks(v, keep) for v in value)
    return value[keep]


class BlockCache:
    """Scan-resistant segmented cache over decoded per-split reader inputs.

    Keys are ``(replica_id, block_tuple, col, projection)`` tuples — the
    leading replica id is the invalidation handle for the store's
    destructive transitions, the block tuple the handle for block-granular
    ones.  ``capacity_bytes=None`` means unbounded (cache everything,
    admission never rejects).  ``scan_resistant=False`` degrades to the
    old pure-LRU policy (kept for A/B measurement in benches/tests)."""

    # ghost-frequency decay: after this many touches, halve every count —
    # TinyLFU's sliding window, so ancient popularity eventually expires
    FREQ_WINDOW = 4096

    def __init__(self, capacity_bytes: Optional[int] = None, *,
                 protected_frac: float = 0.8, scan_resistant: bool = True):
        self.capacity_bytes = capacity_bytes
        self.protected_frac = protected_frac
        self.scan_resistant = scan_resistant
        # key -> (value, nbytes); probation admits, protected holds reuse
        self._probation: "collections.OrderedDict[Hashable, tuple[Any, int]]" \
            = collections.OrderedDict()
        self._protected: "collections.OrderedDict[Hashable, tuple[Any, int]]" \
            = collections.OrderedDict()
        self._protected_bytes = 0
        self._freq: collections.Counter = collections.Counter()
        self._freq_touches = 0
        self.store: Any = None         # set by attach(); heat tie-break
        self.stats = CacheStats()

    def attach(self, store) -> "BlockCache":
        """Install on a ``BlockStore`` — the readers consult
        ``store.block_cache``, the store invalidates on its destructive
        transitions, and the admission filter reads the store's
        ``AccessLog`` for its column-heat signal."""
        store.block_cache = self
        self.store = store
        return self

    def __len__(self) -> int:
        return len(self._probation) + len(self._protected)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._probation or key in self._protected

    @property
    def protected_capacity(self) -> float:
        if self.capacity_bytes is None:
            return float("inf")
        return self.capacity_bytes * self.protected_frac

    # -- admission signal ---------------------------------------------------

    def _touch(self, key: Hashable):
        """Ghost frequency: counts every demand (hit or miss), survives
        eviction, decays by halving every ``FREQ_WINDOW`` touches."""
        self._freq[key] += 1
        self._freq_touches += 1
        if self._freq_touches >= self.FREQ_WINDOW:
            self._freq = collections.Counter(
                {k: c >> 1 for k, c in self._freq.items() if c > 1})
            self._freq_touches = 0

    def _score(self, key: Hashable) -> tuple[int, int]:
        """(ghost frequency, governor column heat) — the admission score.
        Heat is the store AccessLog's lifetime (hits + misses) for the
        key's (replica, filter column): reusing the governor's own
        frequency data, a key of a column with real query history outranks
        a one-touch scan over a cold column at equal key frequency."""
        heat = 0
        log = getattr(self.store, "access_log", None)
        if log is not None and isinstance(key, tuple) and len(key) >= 3:
            heat = log.heat(key[0], key[2])
        return (self._freq.get(key, 0), heat)

    # -- read/write ---------------------------------------------------------

    def get(self, key: Hashable):
        """-> cached value or None; counts the hit/miss and, on a
        probation hit, promotes the entry to the protected segment."""
        from repro.kernels import ops
        self._touch(key)
        ent = self._protected.get(key)
        if ent is not None:
            self._protected.move_to_end(key)
        else:
            ent = self._probation.pop(key, None)
            if ent is not None:                 # proven reuse: promote
                self._protected[key] = ent
                self._protected_bytes += ent[1]
                self.stats.promotions += 1
                self._shrink_protected()
        if ent is None:
            self.stats.misses += 1
            ops.DISPATCH_COUNTS["cache_misses"] += 1
            return None
        self.stats.hits += 1
        ops.DISPATCH_COUNTS["cache_hits"] += 1
        return ent[0]

    def _shrink_protected(self):
        """SLRU overflow: protected LRU demotes back to probation MRU —
        it stays resident but becomes evictable again."""
        while self._protected_bytes > self.protected_capacity \
                and len(self._protected) > 1:
            k, ent = self._protected.popitem(last=False)
            self._protected_bytes -= ent[1]
            self._probation[k] = ent

    def _eviction_order(self):
        """(segment, key, nbytes) in eviction order: probation LRU first,
        then (only if probation runs dry) protected LRU."""
        for k, (_, nb) in self._probation.items():
            yield self._probation, k, nb
        for k, (_, nb) in self._protected.items():
            yield self._protected, k, nb

    def put(self, key: Hashable, value: Any):
        from repro.kernels import ops
        nbytes = _nbytes(value)
        if self.capacity_bytes is not None and nbytes > self.capacity_bytes:
            return                       # larger than the whole budget
        for seg in (self._probation, self._protected):
            old = seg.pop(key, None)
            if old is not None:          # refresh in place (same segment)
                self.stats.bytes_cached -= old[1]
                if seg is self._protected:
                    self._protected_bytes += nbytes - old[1]
                seg[key] = (value, nbytes)
                self.stats.bytes_cached += nbytes
                # a refresh that GREW must still respect capacity — evict
                # around the refreshed entry (it's resident, not a
                # candidate, so the admission filter doesn't apply)
                self._evict_over_capacity(exclude=key)
                self._bump_peak()
                return
        if self.capacity_bytes is not None:
            need = self.stats.bytes_cached + nbytes - self.capacity_bytes
            if need > 0:
                victims, freed = [], 0
                for seg, k, nb in self._eviction_order():
                    if freed >= need:
                        break
                    victims.append((seg, k, nb))
                    freed += nb
                if self.scan_resistant:
                    cand = self._score(key)
                    if any(self._score(k) >= cand for _, k, _ in victims):
                        # a would-be victim is at least as valuable as the
                        # candidate: keep the residents (scan resistance)
                        self.stats.admission_rejects += 1
                        ops.DISPATCH_COUNTS["cache_admission_rejects"] += 1
                        return
                for seg, k, nb in victims:
                    del seg[k]
                    self.stats.bytes_cached -= nb
                    if seg is self._protected:
                        self._protected_bytes -= nb
                    self.stats.evictions += 1
        self._probation[key] = (value, nbytes)
        self.stats.bytes_cached += nbytes
        self._bump_peak()

    def _evict_over_capacity(self, exclude: Hashable = None):
        """Plain capacity eviction (no admission filter), optionally
        sparing one resident key."""
        if self.capacity_bytes is None:
            return
        while self.stats.bytes_cached > self.capacity_bytes:
            victim = next(((seg, k, nb) for seg, k, nb
                           in self._eviction_order() if k != exclude), None)
            if victim is None:
                return
            seg, k, nb = victim
            del seg[k]
            self.stats.bytes_cached -= nb
            if seg is self._protected:
                self._protected_bytes -= nb
            self.stats.evictions += 1

    def _bump_peak(self):
        self.stats.peak_bytes = max(self.stats.peak_bytes,
                                    self.stats.bytes_cached)

    # -- invalidation -------------------------------------------------------

    def invalidate_replica(self, replica_id: int):
        """Drop every entry of one replica — called by the store's
        destructive transitions (index commit / demotion)."""
        for seg in (self._probation, self._protected):
            for k in [k for k in seg if k[0] == replica_id]:
                _, nbytes = seg.pop(k)
                self.stats.bytes_cached -= nbytes
                if seg is self._protected:
                    self._protected_bytes -= nbytes
                self.stats.invalidations += 1

    def invalidate_blocks(self, replica_id: int, block_ids: Sequence[int]):
        """Drop the BAD blocks from every entry whose gathered block set
        intersects ``block_ids`` — quarantine/repair touch single blocks,
        so evicting the whole replica would throw away every hot split for
        one bad block.  An entry with surviving blocks is re-keyed to the
        surviving subset and re-accounted at its TRUE RESIDUAL byte size
        (sliced arrays, recounted) — capacity eviction must never charge
        the at-admission size for a partially invalidated entry."""
        bad = {int(b) for b in block_ids}
        for seg in (self._probation, self._protected):
            stale = [k for k in seg
                     if k[0] == replica_id and bad.intersection(k[1])]
            for k in stale:
                value, nbytes = seg.pop(k)
                self.stats.bytes_cached -= nbytes
                if seg is self._protected:
                    self._protected_bytes -= nbytes
                self.stats.invalidations += 1
                keep = np.asarray([i for i, b in enumerate(k[1])
                                   if int(b) not in bad], dtype=np.int64)
                if len(keep) == 0:
                    continue
                new_key = (k[0], tuple(k[1][i] for i in keep)) + k[2:]
                if new_key in seg or new_key in self._probation \
                        or new_key in self._protected:
                    continue             # residual already cached directly
                residual = _slice_blocks(value, keep)
                res_bytes = _nbytes(residual)    # true residual, recounted
                seg[new_key] = (residual, res_bytes)
                self.stats.bytes_cached += res_bytes
                if seg is self._protected:
                    self._protected_bytes += res_bytes
                self.stats.partial_invalidations += 1

    def clear(self):
        self.stats.invalidations += len(self)
        self._probation.clear()
        self._protected.clear()
        self._protected_bytes = 0
        self.stats.bytes_cached = 0

    # -- auditing -----------------------------------------------------------

    def recount(self) -> int:
        """Recompute resident bytes from the cached values themselves —
        the byte-accounting oracle ``stats.bytes_cached`` must equal (the
        regression tests assert it after every mutation kind)."""
        total = 0
        for seg in (self._probation, self._protected):
            for value, nbytes in seg.values():
                actual = _nbytes(value)
                assert nbytes == actual, \
                    f"accounting drift: stored {nbytes} != actual {actual}"
                total += actual
        return total


# ---------------------------------------------------------------------------
# Tier 2: the query-result cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResultEntry:
    """One materialized answer: the matching rows (projection + __rowid__,
    host arrays), plus the attribution recipe — per-replica (replica_id,
    index-scanned blocks, full-scanned blocks) totals of the read that
    produced it, replayed through ``governor.attribute_read`` on every hit
    so cached traffic keeps feeding the AccessLog."""
    rows: dict
    n_rows: int
    attribution: tuple            # ((replica_id, n_index, n_full), ...)
    nbytes: int = 0


@dataclasses.dataclass
class ResultCacheStats:
    hits: int = 0
    misses: int = 0
    subsumed_hits: int = 0        # served by narrowing a superset range
    evictions: int = 0
    invalidations: int = 0
    bytes_cached: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class ResultCache:
    """LRU cache of materialized query answers, keyed
    ``(filter col, lo, hi, projection, store version)``.

    The store-version key component makes staleness STRUCTURAL: every
    destructive transition bumps ``BlockStore.version`` (and calls
    ``invalidate_store`` to reclaim the memory), so an entry filled
    against an older store state can never match a lookup.  A lookup
    first tries the exact range; failing that, if the filter column is in
    the projection, it narrows the most recently used SUBSUMING range
    (cached ``lo' <= lo <= hi <= hi'``) by re-filtering its materialized
    rows — repeated AND contained ranges both skip the scan."""

    def __init__(self, capacity_bytes: Optional[int] = None):
        self.capacity_bytes = capacity_bytes
        self._entries: "collections.OrderedDict[tuple, ResultEntry]" \
            = collections.OrderedDict()
        self.stats = ResultCacheStats()

    def attach(self, store) -> "ResultCache":
        store.result_cache = self
        return self

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self):
        return list(self._entries)

    @staticmethod
    def make_key(col: str, lo: int, hi: int, projection, version: int):
        return (col, int(lo), int(hi), tuple(projection), int(version))

    def lookup(self, col: str, lo: int, hi: int, projection,
               version: int) -> Optional[ResultEntry]:
        from repro.kernels import ops
        proj = tuple(projection)
        key = self.make_key(col, lo, hi, proj, version)
        ent = self._entries.get(key)
        if ent is not None:
            self._entries.move_to_end(key)
            self.stats.hits += 1
            ops.DISPATCH_COUNTS["result_cache_hits"] += 1
            return ent
        if col in proj:
            # subsumption: a cached superset range answers a contained one
            # by re-filtering its rows — possible only when the filter
            # column was projected (the cached rows carry its values)
            for k in reversed(self._entries):          # MRU first
                if (k[0] == col and k[3] == proj and k[4] == version
                        and k[1] <= lo and hi <= k[2]):
                    donor = self._entries[k]
                    self._entries.move_to_end(k)
                    vals = donor.rows[col]
                    m = (vals >= lo) & (vals <= hi)
                    # fancy indexing copies, so there is no aliasing here;
                    # freeze anyway so exact and subsumed hits expose the
                    # same read-only contract
                    rows = {}
                    for c, v in donor.rows.items():
                        nv = v[m]
                        nv.setflags(write=False)
                        rows[c] = nv
                    self.stats.hits += 1
                    self.stats.subsumed_hits += 1
                    ops.DISPATCH_COUNTS["result_cache_hits"] += 1
                    return ResultEntry(rows=rows, n_rows=int(m.sum()),
                                       attribution=donor.attribution)
        self.stats.misses += 1
        ops.DISPATCH_COUNTS["result_cache_misses"] += 1
        return None

    def put(self, col: str, lo: int, hi: int, projection, version: int,
            rows: dict, attribution: tuple):
        nbytes = _nbytes(rows)
        if self.capacity_bytes is not None and nbytes > self.capacity_bytes:
            return
        # The entry OWNS these arrays from here on, and hits hand them back
        # without copying (a shallow dict copy shares the buffers).  Freeze
        # them so a caller mutating its answer raises instead of silently
        # corrupting every future hit for this key.  (Tier 1 needs no such
        # guard: BlockCache values are jax.Arrays, immutable by
        # construction — see _gather_replica_inputs.)
        for v in rows.values():
            if isinstance(v, np.ndarray):
                v.setflags(write=False)
        key = self.make_key(col, lo, hi, projection, version)
        old = self._entries.pop(key, None)
        if old is not None:
            self.stats.bytes_cached -= old.nbytes
        self._entries[key] = ResultEntry(rows=rows, n_rows=len(
            next(iter(rows.values()))) if rows else 0,
            attribution=tuple(attribution), nbytes=nbytes)
        self.stats.bytes_cached += nbytes
        while (self.capacity_bytes is not None
               and self.stats.bytes_cached > self.capacity_bytes):
            _, dropped = self._entries.popitem(last=False)       # LRU out
            self.stats.bytes_cached -= dropped.nbytes
            self.stats.evictions += 1

    def invalidate_store(self):
        """Destructive store transition: every cached answer (and its
        attribution recipe — the plan it replays just changed) is stale.
        The version key already makes them unreachable; this reclaims the
        memory and counts the event."""
        self.stats.invalidations += len(self._entries)
        self._entries.clear()
        self.stats.bytes_cached = 0
