"""Vectorized ASCII -> binary parsing (the HAIL client's to-PAX conversion).

The paper's client parses text logs row-by-row while uploading; the CPU cost
rides the I/O-bound pipeline for free.  Here the parse is a jit'd tensor
program: bytes (rows, row_width) -> per-column int32 values + a bad-record
mask.  A row is *bad* when any of its digit positions is not '0'..'9'
(paper §3.1: bad records are separated into a special part of the block and
handed to the map function with a flag).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schema import Schema


def format_rows(schema: Schema, cols: dict[str, np.ndarray],
                bad_fraction: float = 0.0, seed: int = 1) -> np.ndarray:
    """Host-side encoder: columns -> uint8 text block (rows, row_width)."""
    n = len(next(iter(cols.values())))
    parts = []
    for c in schema.columns:
        v = np.asarray(cols[c.name]).astype(np.int64)
        w = c.ascii_width
        digits = np.zeros((n, w), dtype=np.uint8)
        rem = v.copy()
        for i in range(w - 1, -1, -1):
            digits[:, i] = (rem % 10).astype(np.uint8) + ord("0")
            rem //= 10
        parts.append(digits)
    nl = np.full((n, 1), ord("\n"), dtype=np.uint8)
    out = np.concatenate(parts + [nl], axis=1)
    if bad_fraction > 0:
        r = np.random.default_rng(seed)
        bad = r.random(n) < bad_fraction
        idx = np.nonzero(bad)[0]
        # corrupt a random byte with a non-digit
        out[idx, r.integers(0, out.shape[1] - 1, len(idx))] = ord("x")
    return out


def parse_block(schema: Schema, raw: jax.Array) -> tuple[dict[str, jax.Array], jax.Array]:
    """raw (rows, row_width) uint8 -> ({col: int32 (rows,)}, bad (rows,) bool)."""
    digits = raw.astype(jnp.int32) - ord("0")
    cols: dict[str, jax.Array] = {}
    bad = jnp.zeros(raw.shape[0], bool)
    off = 0
    for c in schema.columns:
        w = c.ascii_width
        d = jax.lax.dynamic_slice_in_dim(digits, off, w, axis=1)
        bad |= jnp.any((d < 0) | (d > 9), axis=1)
        # Horner scheme in int32: partial values never exceed the final value,
        # so valid rows (schema contract: values < 2^31) cannot overflow.
        val = jnp.zeros(raw.shape[0], jnp.int32)
        for i in range(w):
            val = val * 10 + d[:, i]
        cols[c.name] = val
        off += w
    # zero out bad rows (they live in the block's bad-record section)
    cols = {k: jnp.where(bad, 0, v) for k, v in cols.items()}
    return cols, bad


def block_binary_bytes(schema: Schema, n_rows: int) -> int:
    """Size of the binary PAX representation (int32 per column)."""
    return 4 * len(schema.columns) * n_rows


def block_ascii_bytes(schema: Schema, n_rows: int) -> int:
    return schema.row_ascii_width * n_rows
