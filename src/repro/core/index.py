"""Sparse clustered index (paper §3.5, Figure 2).

After sorting a block by the index key, the index is a single root directory
of partition-minimum keys over fixed 1,024-row partitions; leaves (the
partitions) are contiguous on disk/HBM so child offsets are implicit
(leaf_id * partition_size).  A range lookup binary-searches the root in main
memory for the first and last qualifying partition, streams exactly those
partitions, and post-filters — the paper's argument for why a single-level
sparse tree beats multi-level trees at <=1GB blocks (seek-dominated) maps to
one VMEM-resident root array per block here.

The Pallas kernels in repro/kernels mirror these reference semantics
(index_search, pax_scan); this module is the pure-jnp oracle.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

PARTITION = 1024  # rows per leaf partition (paper's default)


@dataclasses.dataclass(frozen=True)
class ClusteredIndex:
    """Root directory for one block: mins (n_parts,), key column name."""
    key: str
    partition_size: int


def sort_permutation(key_col: jax.Array, bad: jax.Array | None = None) -> jax.Array:
    """Permutation sorting the block by key; bad records go to the tail
    (the paper's 'special part of the data block').  Keys are int32 with
    INT32_MAX reserved as the bad-record sentinel (schema contract)."""
    k = key_col
    if bad is not None:
        big = jnp.iinfo(jnp.int32).max
        k = jnp.where(bad, big, k)
    return jnp.argsort(k, stable=True)


def build_root(sorted_key: jax.Array, partition_size: int = PARTITION) -> jax.Array:
    """Partition minima (the root directory). rows % partition_size == 0."""
    return sorted_key[::partition_size]


def build_block_roots(sorted_keys: jax.Array,
                      partition_size: int = PARTITION) -> jax.Array:
    """Batched ``build_root``: (k_blocks, rows) -> (k_blocks, n_parts)."""
    return sorted_keys[:, ::partition_size]


def merge_block_roots(mins: jax.Array, block_ids,
                      new_mins: jax.Array) -> jax.Array:
    """Incremental root-directory merge (adaptive indexing): splice freshly
    built per-block root directories into a replica's (n_blocks, n_parts)
    directory.  Functional — readers holding the old directory are
    unaffected; the store swaps in the merged one at commit."""
    return mins.at[jnp.asarray(block_ids)].set(new_mins)


def search_range(mins: jax.Array, lo, hi, partition_size: int,
                 n_rows: int) -> tuple[jax.Array, jax.Array]:
    """-> (row_start, row_end) half-open row range covering [lo, hi].

    p_first = last partition whose min <= lo (clamped to 0);
    p_last  = last partition whose min <= hi.
    """
    p_first = jnp.maximum(
        jnp.searchsorted(mins, lo, side="right").astype(jnp.int32) - 1, 0)
    p_last = jnp.maximum(
        jnp.searchsorted(mins, hi, side="right").astype(jnp.int32) - 1, 0)
    row_start = p_first * partition_size
    row_end = jnp.minimum((p_last + 1) * partition_size, n_rows)
    return row_start, row_end


def index_scan_mask(sorted_key: jax.Array, mins: jax.Array, lo, hi,
                    partition_size: int = PARTITION) -> jax.Array:
    """Qualifying-row mask touching only rows inside the partition range.

    (In the fixed-shape jnp oracle the mask is full-length; the *read set*
    is row_start:row_end — kernels and cost accounting use that.)
    """
    n = sorted_key.shape[0]
    row_start, row_end = search_range(mins, lo, hi, partition_size, n)
    r = jnp.arange(n, dtype=jnp.int32)
    in_range = (r >= row_start) & (r < row_end)
    pred = (sorted_key >= lo) & (sorted_key <= hi)
    return in_range & pred


def full_scan_mask(key_col: jax.Array, lo, hi) -> jax.Array:
    return (key_col >= lo) & (key_col <= hi)


def rows_read_fraction(mins: jax.Array, lo, hi, partition_size: int,
                       n_rows: int) -> jax.Array:
    """Fraction of the block the index scan must read (I/O model)."""
    row_start, row_end = search_range(mins, lo, hi, partition_size, n_rows)
    return (row_end - row_start) / n_rows
