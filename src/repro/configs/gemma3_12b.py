"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
5:1 local(SWA-1024):global, head_dim=256, qk-norm. [hf:google/gemma-3-*-pt]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelCfg, StackCfg, dense_layer

D, H, KV, FF, V, HD, W = 3840, 16, 8, 15360, 262144, 256, 1024

_local = dense_layer(D, H, KV, FF, head_dim=HD, window=W,
                     rope_theta=10_000.0, qk_norm=True)
_global = dense_layer(D, H, KV, FF, head_dim=HD, window=None,
                      rope_theta=1_000_000.0, qk_norm=True)

# 48 layers = 8 x (5 local + 1 global)
CONFIG = ModelCfg(
    name="gemma3-12b",
    family="dense",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_local,) * 5 + (_global,), n_groups=8),
    tie_embeddings=True,
    embed_scale=True,
)


def reduced() -> ModelCfg:
    lo = dense_layer(64, 4, 2, 128, head_dim=16, window=8, qk_norm=True)
    gl = dense_layer(64, 4, 2, 128, head_dim=16, window=None, qk_norm=True)
    return dataclasses.replace(
        CONFIG, name="gemma3-12b-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(lo, lo, gl), n_groups=2))
