"""zamba2-2.7b [hybrid]: 54L d_model=2560, Mamba2 backbone (ssm_state=64) +
shared-weight attention blocks (32H, kv=32 i.e. MHA) interleaved 5:1.
54 = 9 x (5 mamba2 + 1 shared attn block).  [arXiv:2411.15242]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (AttnCfg, LayerCfg, Mamba2Cfg, MlpCfg,
                                ModelCfg, StackCfg)

D, V = 2560, 32000

_mamba = LayerCfg(kind="mamba2",
                  ssm=Mamba2Cfg(d_inner=2 * D, d_state=64, head_dim=64))
_shared_impl = LayerCfg(
    kind="attn_mlp",
    attn=AttnCfg(n_heads=32, n_kv=32, head_dim=80),
    mlp=MlpCfg(d_ff=10240),
)
_shared_slot = LayerCfg(kind="shared")

CONFIG = ModelCfg(
    name="zamba2-2.7b",
    family="hybrid",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_mamba,) * 5 + (_shared_slot,), n_groups=9,
                   shared=_shared_impl),
    tie_embeddings=True,
)


def reduced() -> ModelCfg:
    m = LayerCfg(kind="mamba2",
                 ssm=Mamba2Cfg(d_inner=128, d_state=16, head_dim=16, chunk=16))
    sh = LayerCfg(kind="attn_mlp",
                  attn=AttnCfg(n_heads=4, n_kv=4, head_dim=16),
                  mlp=MlpCfg(d_ff=128))
    return dataclasses.replace(
        CONFIG, name="zamba2-2.7b-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(m, m, LayerCfg(kind="shared")), n_groups=2,
                       shared=sh))
