"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelCfg, StackCfg, moe_layer

D, H, KV, FF, V, E, K, W = 6144, 48, 8, 16384, 32768, 8, 2, 4096

_layer = moe_layer(D, H, KV, FF, n_experts=E, top_k=K, window=W)

CONFIG = ModelCfg(
    name="mixtral-8x22b",
    family="moe",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_layer,), n_groups=56),
    tie_embeddings=False,
)


def reduced() -> ModelCfg:
    l = moe_layer(64, 4, 2, 128, n_experts=4, top_k=2, window=8,
                  capacity_factor=4.0)
    return dataclasses.replace(
        CONFIG, name="mixtral-8x22b-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(l,), n_groups=2))
