"""Config registry: --arch <id> -> ModelCfg (full) / reduced (smoke tests)."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelCfg, ShapeCfg  # re-export

ARCHS: dict[str, str] = {
    "gemma3-4b": "repro.configs.gemma3_4b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "whisper-medium": "repro.configs.whisper_medium",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
}


def get_config(name: str) -> ModelCfg:
    return importlib.import_module(ARCHS[name]).CONFIG


def get_reduced(name: str) -> ModelCfg:
    return importlib.import_module(ARCHS[name]).reduced()


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) dry-run cells."""
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name in cfg.skip_shapes and not include_skipped:
                continue
            yield arch, shape.name
