"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual MLP in parallel (Snowflake Arctic's
dense-MoE hybrid).  long_500k skipped: pure full attention.
[hf:Snowflake/snowflake-arctic-base]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelCfg, StackCfg, moe_layer

D, H, KV, FF, V, E, K = 7168, 56, 8, 4864, 32000, 128, 2

_layer = moe_layer(D, H, KV, FF, n_experts=E, top_k=K, dense_residual_ff=FF)

CONFIG = ModelCfg(
    name="arctic-480b",
    family="moe",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_layer,), n_groups=35),
    tie_embeddings=False,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelCfg:
    # generous capacity: smoke tests assert prefill/decode consistency,
    # which requires drop-free routing
    l = moe_layer(64, 4, 2, 128, n_experts=4, top_k=2, dense_residual_ff=128,
                  capacity_factor=4.0)
    return dataclasses.replace(
        CONFIG, name="arctic-480b-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(l,), n_groups=2))
