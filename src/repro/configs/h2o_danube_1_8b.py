"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix with sliding-window attention (4096).
[arXiv:2401.16818]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelCfg, StackCfg, dense_layer

D, H, KV, FF, V, W = 2560, 32, 8, 6912, 32000, 4096

_layer = dense_layer(D, H, KV, FF, window=W)

CONFIG = ModelCfg(
    name="h2o-danube-1.8b",
    family="dense",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_layer,), n_groups=24),
    tie_embeddings=False,
)


def reduced() -> ModelCfg:
    l = dense_layer(64, 4, 2, 128, head_dim=16, window=8)
    return dataclasses.replace(
        CONFIG, name="h2o-danube-1.8b-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(l,), n_groups=3))
