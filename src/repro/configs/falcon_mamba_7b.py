"""falcon-mamba-7b [ssm]: 64L d_model=4096, attention-free Mamba1,
d_inner=8192, d_state=16, dt_rank=256, conv4, vocab=65024.
[arXiv:2410.05355]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import LayerCfg, Mamba1Cfg, ModelCfg, StackCfg

D, V = 4096, 65024

_layer = LayerCfg(kind="mamba1",
                  ssm=Mamba1Cfg(d_inner=2 * D, d_state=16, dt_rank=D // 16))

CONFIG = ModelCfg(
    name="falcon-mamba-7b",
    family="ssm",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_layer,), n_groups=64),
    tie_embeddings=True,
)


def reduced() -> ModelCfg:
    l = LayerCfg(kind="mamba1",
                 ssm=Mamba1Cfg(d_inner=128, d_state=8, dt_rank=8, chunk=16))
    return dataclasses.replace(
        CONFIG, name="falcon-mamba-7b-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(l,), n_groups=3))
