"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, full attention, rope theta 500k, tied embeddings.
long_500k skipped: pure full attention (see DESIGN.md). [hf:meta-llama]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelCfg, StackCfg, dense_layer

D, H, KV, FF, V = 2048, 32, 8, 8192, 128256

_layer = dense_layer(D, H, KV, FF, rope_theta=500_000.0)

CONFIG = ModelCfg(
    name="llama3.2-1b",
    family="dense",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_layer,), n_groups=16),
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelCfg:
    l = dense_layer(64, 4, 2, 128, head_dim=16)
    return dataclasses.replace(
        CONFIG, name="llama3.2-1b-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(l,), n_groups=3))
