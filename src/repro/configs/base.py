"""Config dataclasses for the model zoo + the four assigned input shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Sub-layer configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    n_heads: int
    n_kv: int
    head_dim: int
    window: Optional[int] = None          # None = full attention
    rope_theta: float = 10000.0
    mrope_section: Optional[tuple[int, ...]] = None
    causal: bool = True
    cross: bool = False                   # cross-attention (enc-dec decoder)
    qk_norm: bool = False                 # gemma3-style per-head RMS on q,k
    softcap: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class MlpCfg:
    d_ff: int
    gated: bool = True                    # SwiGLU (gated) vs plain GeLU MLP


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    dense_residual_ff: Optional[int] = None   # arctic: parallel dense MLP


@dataclasses.dataclass(frozen=True)
class Mamba1Cfg:
    d_inner: int
    d_state: int = 16
    dt_rank: int = 0                      # 0 -> d_model // 16
    conv_width: int = 4
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class Mamba2Cfg:
    d_inner: int
    d_state: int = 64
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class LayerCfg:
    """One position in the stack pattern."""

    kind: str                 # 'attn_mlp' | 'mamba1' | 'mamba2' | 'shared'
    attn: Optional[AttnCfg] = None
    mlp: Optional[MlpCfg] = None
    moe: Optional[MoECfg] = None
    ssm: Optional[Mamba1Cfg | Mamba2Cfg] = None


@dataclasses.dataclass(frozen=True)
class StackCfg:
    pattern: tuple[LayerCfg, ...]
    n_groups: int
    tail: tuple[LayerCfg, ...] = ()
    shared: Optional[LayerCfg] = None     # weights for kind='shared' positions

    @property
    def n_layers(self) -> int:
        return self.n_groups * len(self.pattern) + len(self.tail)


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    name: str
    family: str                           # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    vocab: int
    stack: StackCfg
    encoder: Optional[StackCfg] = None    # whisper
    tie_embeddings: bool = True
    embed_scale: bool = False             # gemma: x *= sqrt(d_model)
    embed_inputs: bool = True             # False: input_specs feeds embeddings
    norm_eps: float = 1e-6
    compute_dtype: object = jnp.bfloat16
    # which assigned shapes apply (long_500k skipped for pure full-attention)
    skip_shapes: tuple[str, ...] = ()

    @property
    def n_layers(self) -> int:
        return self.stack.n_layers


# ---------------------------------------------------------------------------
# Assigned input shapes (identical for every LM-family arch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                             # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def dense_layer(d_model: int, n_heads: int, n_kv: int, d_ff: int,
                head_dim: int | None = None, window: int | None = None,
                rope_theta: float = 10000.0, qk_norm: bool = False,
                mrope: tuple[int, ...] | None = None, cross: bool = False,
                causal: bool = True) -> LayerCfg:
    return LayerCfg(
        kind="attn_mlp",
        attn=AttnCfg(n_heads=n_heads, n_kv=n_kv,
                     head_dim=head_dim or d_model // n_heads, window=window,
                     rope_theta=rope_theta, qk_norm=qk_norm,
                     mrope_section=mrope, cross=cross, causal=causal),
        mlp=MlpCfg(d_ff=d_ff),
    )


def moe_layer(d_model: int, n_heads: int, n_kv: int, d_ff: int, n_experts: int,
              top_k: int, head_dim: int | None = None, window: int | None = None,
              dense_residual_ff: int | None = None,
              capacity_factor: float = 1.25) -> LayerCfg:
    return LayerCfg(
        kind="attn_mlp",
        attn=AttnCfg(n_heads=n_heads, n_kv=n_kv,
                     head_dim=head_dim or d_model // n_heads, window=window),
        moe=MoECfg(n_experts=n_experts, top_k=top_k, d_ff=d_ff,
                   capacity_factor=capacity_factor,
                   dense_residual_ff=dense_residual_ff),
    )
