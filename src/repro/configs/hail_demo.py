"""The paper's OWN configuration surface (its 'architecture' is a cluster +
datasets + indexing policy, not a model): presets matching §6.1–6.2 scaled
to this container, used by the benchmarks and examples."""
from __future__ import annotations

import dataclasses

from repro.core.mapreduce import ClusterModel
from repro.core.schema import SYNTHETIC, USERVISITS


@dataclasses.dataclass(frozen=True)
class HailDemoCfg:
    name: str
    schema: object
    sort_keys: tuple            # one clustered index per replica
    rows_per_block: int
    n_blocks: int
    partition_size: int
    cluster: ClusterModel


# the paper: 10-node physical cluster, 64MB blocks, 20GB UserVisits/node,
# replication 3, indexes visitDate/sourceIP/adRevenue (§6.4.1)
USERVISITS_DEMO = HailDemoCfg(
    name="uservisits-10node",
    schema=USERVISITS,
    sort_keys=("visitDate", "sourceIP", "adRevenue"),
    rows_per_block=4096,
    n_blocks=40,
    partition_size=1024,
    cluster=ClusterModel(n_nodes=10, map_slots=4, sched_overhead_s=3.0,
                         disk_bw=100e6),
)

# Synthetic: 19 int attributes, 13GB/node, indexes on attr0..2 (§6.2)
SYNTHETIC_DEMO = HailDemoCfg(
    name="synthetic-10node",
    schema=SYNTHETIC,
    sort_keys=("attr0", "attr1", "attr2"),
    rows_per_block=4096,
    n_blocks=40,
    partition_size=1024,
    cluster=ClusterModel(n_nodes=10, map_slots=4, sched_overhead_s=3.0,
                         disk_bw=100e6),
)

# scale-out presets (Fig 5): 50/100-node EC2 cc1.4xlarge
SCALEOUT_50 = dataclasses.replace(
    USERVISITS_DEMO, name="uservisits-50node",
    cluster=ClusterModel(n_nodes=50, map_slots=4))
SCALEOUT_100 = dataclasses.replace(
    USERVISITS_DEMO, name="uservisits-100node",
    cluster=ClusterModel(n_nodes=100, map_slots=4))
