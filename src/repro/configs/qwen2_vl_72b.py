"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE (t/h/w sections 16/24/24 of head_dim/2=64), dynamic
resolution.  The vision frontend is a STUB per the assignment:
input_specs() provides precomputed patch embeddings for train/prefill
(embed_inputs=False); decode embeds generated text tokens via the table.
long_500k skipped: pure full attention.  [arXiv:2409.12191]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelCfg, StackCfg, dense_layer

D, H, KV, FF, V = 8192, 64, 8, 29568, 152064

_layer = dense_layer(D, H, KV, FF, rope_theta=1_000_000.0, mrope=(16, 24, 24))

CONFIG = ModelCfg(
    name="qwen2-vl-72b",
    family="vlm",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_layer,), n_groups=80),
    tie_embeddings=False,
    embed_inputs=False,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelCfg:
    l = dense_layer(64, 4, 2, 128, head_dim=16, mrope=(2, 3, 3))
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-72b-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(l,), n_groups=3))
