"""whisper-medium [audio]: enc-dec, 24L encoder + 24L decoder, d_model=1024
16H (kv=16, MHA) d_ff=4096 vocab=51865, plain-GeLU MLPs.  The conv audio
frontend is a STUB per the assignment: input_specs() provides precomputed
frame embeddings (B, S, D); the transformer backbone is fully real.
long_500k skipped: pure full attention + enc-dec.  [arXiv:2212.04356]"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (AttnCfg, LayerCfg, MlpCfg, ModelCfg, StackCfg)

D, H, KV, FF, V = 1024, 16, 16, 4096, 51865


def _enc_layer(d, h, kv, ff, hd=None):
    return LayerCfg(kind="attn_mlp",
                    attn=AttnCfg(n_heads=h, n_kv=kv, head_dim=hd or d // h,
                                 causal=False),
                    mlp=MlpCfg(d_ff=ff, gated=False))


def _dec_layer(d, h, kv, ff, hd=None):
    return LayerCfg(kind="attn_mlp",
                    attn=AttnCfg(n_heads=h, n_kv=kv, head_dim=hd or d // h,
                                 cross=True),
                    mlp=MlpCfg(d_ff=ff, gated=False))


CONFIG = ModelCfg(
    name="whisper-medium",
    family="audio",
    d_model=D,
    vocab=V,
    stack=StackCfg(pattern=(_dec_layer(D, H, KV, FF),), n_groups=24),
    encoder=StackCfg(pattern=(_enc_layer(D, H, KV, FF),), n_groups=24),
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)


def reduced() -> ModelCfg:
    return dataclasses.replace(
        CONFIG, name="whisper-medium-reduced", d_model=64, vocab=512,
        stack=StackCfg(pattern=(_dec_layer(64, 4, 4, 128, 16),), n_groups=2),
        encoder=StackCfg(pattern=(_enc_layer(64, 4, 4, 128, 16),), n_groups=2))
