"""Production serving launcher: batched prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.dist.sharding import init_params, map_specs, TensorSpec
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import model_specs
from repro.train.step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = make_production_mesh() if n_dev >= 256 else make_host_mesh()

    def to_bf16(s: TensorSpec):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return TensorSpec(s.shape, s.axes, jnp.bfloat16, s.init, s.scale)
        return s

    params = init_params(map_specs(to_bf16, model_specs(cfg)),
                         jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen

    with mesh:
        prefill = jax.jit(make_prefill_step(cfg, mesh=mesh, max_len=max_len))
        decode = jax.jit(make_decode_step(cfg, mesh=mesh), donate_argnums=(1,))

        batch = {}
        key = jax.random.PRNGKey(1)
        if cfg.embed_inputs:
            batch["tokens"] = jax.random.randint(
                key, (args.batch, args.prompt_len), 0, cfg.vocab)
        else:
            batch["inputs"] = jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)
        if cfg.encoder is not None:
            batch["tokens"] = jax.random.randint(
                key, (args.batch, args.prompt_len), 0, cfg.vocab)
            batch["enc_inputs"] = jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)

        t0 = time.time()
        logits, cache = prefill(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        def sample(lg, k):
            if args.temperature <= 0:
                return jnp.argmax(lg, -1).astype(jnp.int32)
            return jax.random.categorical(k, lg / args.temperature).astype(jnp.int32)

        tok = sample(logits, key)
        toks = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            logits, cache = decode(params, cache, {"tokens": tok, "pos": pos})
            tok = sample(logits, jax.random.fold_in(key, i))
            toks.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    print(f"arch={cfg.name} batch={args.batch} mesh={n_dev}dev")
    print(f"prefill: {t_prefill * 1e3:.0f} ms "
          f"({args.batch * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode * 1e3:.0f} ms "
          f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
