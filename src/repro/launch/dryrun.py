import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks the device count on first init.
#   Only the dry-run sees 512 placeholder devices; tests/benches see 1 CPU.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract roofline inputs from the compiled artifact.

  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all                 # every cell, both meshes
  python -m repro.launch.dryrun --all --mesh multi    # pod-axis proof only

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json (incremental;
--force recomputes).  Failures are recorded as JSON with an "error" field —
they are bugs in the sharding config and must be fixed, not skipped.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config
from repro.dist import sharding as sh
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import HW, make_production_mesh
from repro.models.moe import capacity  # noqa: F401 (re-exported for tools)
from repro.train.optimizer import OptCfg
from repro.train.step import (StepCfg, batch_specs, cache_specs_for,
                              make_decode_step, make_prefill_step,
                              make_train_step, train_state_specs)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _float_params_to(dtype):
    def f(s: sh.TensorSpec):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return sh.TensorSpec(s.shape, s.axes, dtype, s.init, s.scale)
        return s
    return f


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active."""
    from repro.models.model import model_specs
    specs = model_specs(cfg)
    leaves = jax.tree.leaves(specs, is_leaf=sh.is_spec)
    total = sum(s.size for s in leaves)
    expert = sum(s.size for s in leaves if s.axes and s.axes[0] == "expert")
    frac = 1.0
    for lc in cfg.stack.pattern + cfg.stack.tail:
        if lc.moe is not None:
            frac = lc.moe.top_k / lc.moe.n_experts
            break
    active = total - expert + expert * frac
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch  # decode: one token / sequence


def build_cell(arch: str, shape_name: str, mesh, rules, step_cfg: StepCfg):
    """Returns (fn, example_args (ShapeDtypeStructs), out_shardings, donate)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    opt = OptCfg()
    if shape.kind == "train":
        fn = make_train_step(cfg, opt, step_cfg, mesh, rules)
        st = train_state_specs(cfg, opt)
        args = (sh.shape_structs(st, mesh, rules),
                sh.shape_structs(batch_specs(cfg, shape), mesh, rules))
        outs = (sh.shardings(st, mesh, rules), None)
        return fn, args, outs, (0,)
    # serving params in bf16
    from repro.models.model import model_specs
    pspecs = sh.map_specs(_float_params_to(jnp.bfloat16), model_specs(cfg))
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, step_cfg, mesh, rules)
        args = (sh.shape_structs(pspecs, mesh, rules),
                sh.shape_structs(batch_specs(cfg, shape), mesh, rules))
        return fn, args, None, ()
    fn = make_decode_step(cfg, step_cfg, mesh, rules)
    cspecs = cache_specs_for(cfg, shape)
    args = (sh.shape_structs(pspecs, mesh, rules),
            sh.shape_structs(cspecs, mesh, rules),
            sh.shape_structs(batch_specs(cfg, shape), mesh, rules))
    outs = (None, sh.shardings(cspecs, mesh, rules))
    return fn, args, outs, (1,)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             step_cfg: StepCfg = None, rules=None, tag: str = "",
             save_hlo: bool = False, out_dir: str = ART_DIR) -> dict:
    step_cfg = step_cfg or StepCfg()
    rules = rules or sh.DEFAULT_RULES
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": int(n_dev), "tag": tag,
           "step_cfg": {"remat": step_cfg.remat, "loss": step_cfg.loss}}
    t0 = time.time()
    try:
        fn, args, outs, donate = build_cell(arch, shape_name, mesh, rules, step_cfg)
        with mesh:
            jitted = jax.jit(fn, out_shardings=outs, donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t1
            mem = compiled.memory_analysis()
            print(mem)
            ca = compiled.cost_analysis()
            ca = ca[0] if isinstance(ca, list) else dict(ca)
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})
            rec["memory"] = {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
            }
            rec["xla_cost"] = {"flops": ca.get("flops"),
                               "bytes_accessed": ca.get("bytes accessed")}
            txt = compiled.as_text()
            rec["hlo_chars"] = len(txt)
            costs = ha.analyze_hlo_text(txt)
            rec["hlo"] = costs
            rec["roofline"] = ha.roofline_terms(costs, HW)
            mf = model_flops(cfg, shape)
            rec["model_flops"] = mf
            hw_total = costs["flops"] * n_dev
            rec["model_over_hlo_flops"] = mf / hw_total if hw_total else None
            rec["roofline_fraction"] = (
                (mf / n_dev / HW["peak_bf16_flops"])
                / rec["roofline"]["step_lower_bound_s"]
                if rec["roofline"]["step_lower_bound_s"] > 0 else None)
            if save_hlo:
                os.makedirs(out_dir, exist_ok=True)
                with open(os.path.join(
                        out_dir, _name(arch, shape_name, mesh_kind, tag) + ".hlo"),
                        "w") as f:
                    f.write(txt)
    except Exception as e:  # noqa: BLE001 - record and surface
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"FAILED {arch} {shape_name} {mesh_kind}: {rec['error']}")
    rec["total_s"] = time.time() - t0
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, _name(arch, shape_name, mesh_kind, tag) + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    status = "ERROR" if "error" in rec else "ok"
    print(f"[{status}] {arch} {shape_name} {mesh_kind} tag={tag!r} "
          f"({rec['total_s']:.1f}s) -> {path}", flush=True)
    return rec


def _name(arch, shape, mesh, tag):
    n = f"{arch}__{shape}__{mesh}"
    return n + (f"__{tag}" if tag else "")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--loss", default="plain", choices=["plain", "chunked"])
    ap.add_argument("--rules", default="baseline",
                    choices=sorted(sh.RULE_PRESETS))
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=ART_DIR)
    args = ap.parse_args()

    step_cfg = StepCfg(remat=args.remat, loss=args.loss)
    rules = sh.RULE_PRESETS[args.rules]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        todo = []
        for arch in ARCHS:
            cfg = get_config(arch)
            for s in SHAPES:
                if s in cfg.skip_shapes:
                    continue
                for m in meshes:
                    todo.append((arch, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape, m) for m in meshes]

    done = failed = 0
    for arch, s, m in todo:
        path = os.path.join(args.out, _name(arch, s, m, args.tag) + ".json")
        if not args.force and os.path.exists(path):
            with open(path) as f:
                if "error" not in json.load(f):
                    continue
        rec = run_cell(arch, s, m, step_cfg=step_cfg, rules=rules,
                       tag=args.tag, save_hlo=args.save_hlo, out_dir=args.out)
        done += 1
        failed += 1 if "error" in rec else 0
    print(f"dry-run complete: {done} cells run, {failed} failures", flush=True)


if __name__ == "__main__":
    main()
