"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` visits a ``while`` body
ONCE, so any model that ``lax.scan``s over layers under-reports FLOPs/bytes by
~n_layers x (verified empirically on this container).  All our stacks scan.
This module parses ``compiled.as_text()`` and walks the call graph,
multiplying costs by loop trip counts (read from the ``known_trip_count``
backend_config XLA attaches to compiled while ops).

All shapes in a post-SPMD module are PER-DEVICE shard shapes, so every number
reported here is per-device; roofline terms divide by per-chip peak rates.

Outputs per module:
  flops            - dot FLOPs (2*M*N*K) + 1/elem for elementwise arith
  dot_flops        - MXU-only part
  hbm_bytes        - fusion-boundary traffic: sum(out + operands) per
                     top-level instruction (fusion internals excluded - they
                     live in registers/VMEM, which is what makes this a much
                     better HBM proxy than per-op accounting)
  coll_bytes       - raw per-device payload per collective kind
  coll_link_bytes  - ICI link-byte model: all-reduce 2(g-1)/g * S,
                     all-gather/reduce-scatter/all-to-all (g-1)/g * S,
                     collective-permute 1 * S, with S = max(out, operands)
                     and g = collective group size.
Validated against cost_analysis() on scan-free toys (tests/test_hlo_analysis).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s4": 1, "u4": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "f8e8m0fnu": 1, "f4e2m1fn": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "negate", "abs", "rsqrt", "sqrt", "compare", "select", "and", "or",
    "xor", "not", "convert", "floor", "ceil", "sign", "cosine", "sine",
    "clamp", "remainder", "atan2", "round-nearest-afz", "round-nearest-even",
    "logistic", "cbrt", "erf", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "reduce", "reduce-window",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "add-dependency", "opt-barrier", "partition-id", "replica-id"}


# ---------------------------------------------------------------------------
# Shape parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


# ---------------------------------------------------------------------------
# Instruction / computation parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_type: str
    operands: list[str]
    attrs: str


_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _split_type_rest(rhs: str) -> tuple[str, str]:
    """rhs = '<type> <opcode>(...), attrs' -> (type, remainder)."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rhs[: i + 1], rhs[i + 1:].lstrip()
        return rhs, ""
    m = re.match(r"^([\w\[\],]+(?:\{[\d,]*\})?(?:\{[^}]*\})*)\s+(.*)$", rhs)
    if m:
        return m.group(1), m.group(2)
    return "", rhs


def parse_module(text: str) -> tuple[dict[str, list[Instr]], str]:
    """Returns ({computation_name: [Instr]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: list[Instr] | None = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw).rstrip()
        if not line:
            continue
        if not line.startswith(" ") and line.endswith("{"):
            m = _COMP_START.match(line)
            if m:
                name = m.group(2)
                comps[name] = []
                cur = comps[name]
                if m.group(1):
                    entry = name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        out_type, rest = _split_type_rest(rhs)
        mo = re.match(r"^([\w\-]+)\(", rest)
        if not mo:
            continue
        opcode = mo.group(1)
        # operands: names inside the first balanced paren group
        depth = 0
        args = ""
        for i in range(len(opcode), len(rest)):
            ch = rest[i]
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    attrs = rest[i + 1:]
                    break
            if depth >= 1:
                args += ch
        else:
            attrs = ""
        operands = re.findall(r"%([\w\.\-]+)", args)
        cur.append(Instr(name, opcode, out_type, operands, attrs))
    assert entry is not None, "no ENTRY computation found"
    return comps, entry


# ---------------------------------------------------------------------------
# Cost walking
# ---------------------------------------------------------------------------


def _trip_count(attrs: str) -> int:
    m = re.search(r'known_trip_count[^\d]*(\d+)', attrs)
    return int(m.group(1)) if m else 1


def _group_size(attrs: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _called(attrs: str, key: str) -> str | None:
    m = re.search(key + r"=%([\w\.\-]+)", attrs)
    return m.group(1) if m else None


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_link_bytes: float = 0.0
    coll_count: dict = dataclasses.field(default_factory=lambda: defaultdict(int))

    def to_dict(self):
        return {"flops": self.flops, "dot_flops": self.dot_flops,
                "hbm_bytes": self.hbm_bytes,
                "coll_bytes": dict(self.coll_bytes),
                "coll_link_bytes": self.coll_link_bytes,
                "coll_count": dict(self.coll_count)}


def _dot_flops(instr: Instr, types: dict[str, str]) -> float:
    out_elems = shape_elems(instr.out_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    k = 1
    if m and instr.operands:
        lhs_type = types.get(instr.operands[0], "")
        dims = _first_shape_dims(lhs_type)
        if m.group(1):
            for di in m.group(1).split(","):
                di = int(di)
                if di < len(dims):
                    k *= dims[di]
    return 2.0 * out_elems * k


def walk(comps: dict[str, list[Instr]], comp_name: str, mult: float,
         costs: Costs, count_bytes: bool = True) -> None:
    instrs = comps.get(comp_name)
    if instrs is None:
        return
    types = {i.name: i.out_type for i in instrs}
    for ins in instrs:
        op = ins.opcode
        if op == "while":
            trip = _trip_count(ins.attrs)
            body = _called(ins.attrs, "body")
            cond = _called(ins.attrs, "condition")
            if body:
                walk(comps, body, mult * trip, costs, count_bytes)
            if cond:
                walk(comps, cond, mult * trip, costs, count_bytes)
            continue
        if op == "conditional":
            for key in ("true_computation", "false_computation"):
                c = _called(ins.attrs, key)
                if c:
                    walk(comps, c, mult, costs, count_bytes)
            for c in re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs):
                for name in re.findall(r"%([\w\.\-]+)", c):
                    walk(comps, name, mult, costs, count_bytes)
            continue
        if op in ("call", "async-start"):
            c = _called(ins.attrs, "to_apply") or _called(ins.attrs, "calls")
            if c:
                walk(comps, c, mult, costs, count_bytes)
            continue
        if op == "fusion":
            c = _called(ins.attrs, "calls")
            if c:
                walk(comps, c, mult, costs, count_bytes=False)  # flops only
            if count_bytes:
                out_b = shape_bytes(ins.out_type)
                opnd_b = sum(shape_bytes(types.get(o, "")) for o in ins.operands)
                costs.hbm_bytes += mult * (out_b + opnd_b)
            continue

        base = op[:-6] if op.endswith("-start") else op
        if op.endswith("-done"):
            continue
        if base in _COLLECTIVES:
            opnd_b = sum(shape_bytes(types.get(o, "")) for o in ins.operands)
            out_b = shape_bytes(ins.out_type)
            payload = max(out_b, opnd_b)
            g = _group_size(ins.attrs)
            if base == "all-reduce":
                link = 2.0 * payload * (g - 1) / max(g, 1)
            elif base == "collective-permute":
                link = float(payload)
            else:
                link = payload * (g - 1) / max(g, 1)
            costs.coll_bytes[base] += mult * payload
            costs.coll_link_bytes += mult * link
            costs.coll_count[base] += int(mult)
            if count_bytes:
                costs.hbm_bytes += mult * (out_b + opnd_b)
            continue

        if op == "dot":
            f = _dot_flops(ins, types)
            costs.flops += mult * f
            costs.dot_flops += mult * f
        elif op == "convolution":
            # approximation: 2 * out_elems * prod(kernel spatial dims * in_ch)
            costs.flops += mult * 2.0 * shape_elems(ins.out_type) * 4
        elif op in _ELEMENTWISE:
            costs.flops += mult * shape_elems(ins.out_type)

        if count_bytes and op not in _SKIP_BYTES:
            out_b = shape_bytes(ins.out_type)
            opnd_b = sum(shape_bytes(types.get(o, "")) for o in ins.operands)
            costs.hbm_bytes += mult * (out_b + opnd_b)


def analyze_hlo_text(text: str) -> dict:
    comps, entry = parse_module(text)
    costs = Costs()
    walk(comps, entry, 1.0, costs)
    return costs.to_dict()


def roofline_terms(costs: dict, hw: dict) -> dict:
    """Per-device seconds per term (HLO shapes are already per-shard)."""
    compute_s = costs["flops"] / hw["peak_bf16_flops"]
    memory_s = costs["hbm_bytes"] / hw["hbm_bw"]
    coll_s = costs["coll_link_bytes"] / hw["ici_bw"]
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, coll_s)
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant,
            "step_lower_bound_s": bound}
