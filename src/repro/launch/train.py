"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real TPU slice this runs the full config on the production mesh; on
this CPU container use --reduced (smoke-scale). Features exercised either
way: sharded train step, HAIL-backed data selection (--hail-select), async
checksummed checkpoints, resume-from-latest, elastic restore onto whatever
mesh the process finds.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ck
from repro.configs import get_config, get_reduced
from repro.dist import sharding as sh
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.optimizer import OptCfg
from repro.train.step import (StepCfg, init_train_state, make_train_step,
                              train_state_specs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--hail-select", default="",
                    help="col:lo:hi training-data selection via HAIL index")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = (make_production_mesh(multi_pod=args.multi_pod) if n_dev >= 256
            else make_host_mesh())
    print(f"arch={cfg.name} devices={n_dev} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opt = OptCfg(lr=args.lr, warmup_steps=min(20, args.steps // 4),
                 total_steps=args.steps)
    step_cfg = StepCfg(remat=args.remat)
    specs = train_state_specs(cfg, opt)

    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        restored, step0 = ck.restore_latest(args.ckpt_dir, state, specs=specs,
                                            mesh=mesh)
        if restored is not None:
            state = restored
            print(f"resumed from step {step0} (elastic restore onto this mesh)")

    with mesh:
        step_fn = jax.jit(
            make_train_step(cfg, opt, step_cfg, mesh),
            out_shardings=(sh.shardings(specs, mesh), None))

        if args.hail_select:
            from repro.data.pipeline import CorpusConfig, HailDataSource, build_corpus
            col, lo, hi = args.hail_select.split(":")
            ccfg = CorpusConfig(n_docs=max(2048, args.batch * 64),
                                seq_width=args.seq + 1, rows_per_block=256,
                                partition_size=64, vocab=cfg.vocab)
            store, _ = build_corpus(ccfg)
            src = iter(HailDataSource(store, ccfg,
                                      select=(col, int(lo), int(hi)),
                                      batch_size=args.batch))
            get_batch = lambda i: next(src)
        else:
            key = jax.random.PRNGKey(1)
            def get_batch(i):
                k = jax.random.fold_in(key, i)
                tok = jax.random.randint(k, (args.batch, args.seq + 1), 0, cfg.vocab)
                return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

        saver = ck.AsyncSaver()
        t0 = time.time()
        start = int(state["step"])
        for i in range(start, args.steps):
            state, metrics = step_fn(state, get_batch(i))
            if (i + 1) % 10 == 0 or i + 1 == args.steps:
                toks = args.batch * args.seq * (i + 1 - start)
                print(f"step {i + 1:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"tok/s={toks / (time.time() - t0):.0f}", flush=True)
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                saver.save(state, args.ckpt_dir, i + 1)
        saver.wait()
    print("done")


if __name__ == "__main__":
    main()
