"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax >= 0.5 wants explicit axis_types; 0.4.x has no AxisType at all
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-mesh, single-device smoke)."""
    return _make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """1-device mesh with production axis names (CPU tests)."""
    return make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware model used for the roofline (per chip).
HW = {
    "peak_bf16_flops": 197e12,      # FLOP/s
    "hbm_bw": 819e9,                # B/s
    "ici_bw": 50e9,                 # B/s per link
    "hbm_bytes": 16e9,
}
