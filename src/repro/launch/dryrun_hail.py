import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines, same contract as dryrun.py

"""HAIL data-plane dry-run: lower + compile the SPMD MapReduce engine and
the upload pipeline on the production meshes (the block-store analogue of
the model-cell dry-run).

  PYTHONPATH=src python -m repro.launch.dryrun_hail
"""
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import mapreduce as mr
from repro.launch.mesh import make_production_mesh

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def run(multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names
    data_axis = "data"
    rows, blocks = 65536, 4096          # 4096 blocks of 64k rows (PAX int32)
    n_buckets = 4096

    sh_blocks = NamedSharding(mesh, P(data_axis))
    keys = jax.ShapeDtypeStruct((blocks, rows), jnp.int32, sharding=sh_blocks)
    vals = jax.ShapeDtypeStruct((blocks, rows), jnp.int32, sharding=sh_blocks)
    mask = jax.ShapeDtypeStruct((blocks, rows), jnp.bool_, sharding=sh_blocks)

    def job(k, v, m):
        return mr.spmd_aggregate(mesh, k, v, m, n_buckets, axis=data_axis)

    with mesh:
        t0 = time.time()
        lowered = jax.jit(job).lower(keys, vals, mask)
        compiled = lowered.compile()
        dt = time.time() - t0
        mem = compiled.memory_analysis()
        print(mem)
        txt = compiled.as_text()
        n_a2a = txt.count(" all-to-all")
        rec = {"kind": "hail_mapreduce", "multi_pod": multi_pod,
               "devices": int(mesh.devices.size), "blocks": blocks,
               "rows": rows, "compile_s": dt,
               "temp_bytes": mem.temp_size_in_bytes,
               "all_to_all_ops": n_a2a}
    name = f"hail_mapreduce__{'multi' if multi_pod else 'single'}.json"
    with open(os.path.join(ART, name), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[ok] HAIL MR dry-run {'multi' if multi_pod else 'single'}-pod: "
          f"{mesh.devices.size} devices, compile {dt:.1f}s, "
          f"{n_a2a} all-to-all ops (the shuffle)")


if __name__ == "__main__":
    run(False)
    run(True)
