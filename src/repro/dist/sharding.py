"""Logical-axis sharding: TensorSpec trees + the rule-based resolver.

Every parameter/activation tensor is declared once as a ``TensorSpec`` with
*logical* axis names ("embed", "mlp", "batch", ...).  ``resolve_pspec`` maps
logical axes to *mesh* axes by priority rules with divisibility fallbacks:

* a rule lists candidate mesh axes per logical axis, best first; a candidate
  may be a COMPOUND tuple like ("pod", "data") meaning shard over both;
* mesh axes absent from the mesh — or of size 1, which shard nothing — are
  dropped from a candidate; for compound candidates the longest PREFIX whose
  size product divides the dimension is used (batch=2 on a (pod=2, data=16)
  mesh shards over just "pod", and ("pod", "data") with pod=1 canonicalises
  to plain "data");
* a mesh axis is used at most once per tensor — later logical axes fall
  through to their next candidate or stay replicated;
* anything that doesn't divide evenly stays replicated (never errors).

The same specs drive initialization (``init_params``), parameter accounting,
``NamedSharding`` construction for jit in/out shardings, and the
``sharding_ctx``/``constrain`` pair that installs with_sharding_constraint
inside traced step functions.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# TensorSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """Shape + logical axes + dtype + init recipe for one tensor."""
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"            # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: Optional[float] = None   # override the fan-in init scale

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return self.size * jnp.dtype(self.dtype).itemsize


def tspec(shape, axes, dtype=jnp.float32, init: str = "normal",
          scale: Optional[float] = None) -> TensorSpec:
    return TensorSpec(tuple(shape), tuple(axes), dtype, init, scale)


def is_spec(x) -> bool:
    return isinstance(x, TensorSpec)


def map_specs(fn: Callable[[TensorSpec], Any], tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def _leaves(tree) -> list[TensorSpec]:
    return [s for s in jax.tree.leaves(tree, is_leaf=is_spec) if is_spec(s)]


def param_count(tree) -> int:
    return sum(s.size for s in _leaves(tree))


def param_bytes(tree) -> int:
    return sum(s.nbytes for s in _leaves(tree))


# ---------------------------------------------------------------------------
# Rules + resolver
# ---------------------------------------------------------------------------

# logical axis -> candidate mesh axes, best first.  Tuples are compound
# (shard over several mesh axes); missing keys mean "always replicated".
DEFAULT_RULES: dict[str, tuple] = {
    # data-parallel axes
    "batch": (("pod", "data"), "data"),
    "layers": (),
    # long-context KV: prefer data, spill to model when batch already took it
    "kv_seq": ("data", "model"),
    # weight axes
    "embed": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "expert": ("model",),
    "expert_mlp": ("model",),
    "ssm_inner": ("model",),
    "conv_dim": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": (),
    "dt_rank": (),
    # activation axes (constrain() names)
    "seq": (),
    "act_embed": (),
    "act_vocab": ("model",),
    "act_mlp": ("model",),
    "act_kv_heads": ("model",),
}

# Variant rule sets for the dry-run's --rules flag.
RULE_PRESETS: dict[str, dict[str, tuple]] = {
    "baseline": DEFAULT_RULES,
    # pure data-parallel: weights replicated, only batch-ish axes sharded
    "dp_only": {"batch": (("pod", "data"), "data"), "kv_seq": ("data",)},
    # fsdp-flavoured: fully shard the embed dimension of weights — over the
    # COMPOUND (data, model) grid when the dim divides, falling back to
    # data alone.  (Plain ("data",) would be byte-identical to
    # DEFAULT_RULES, making the preset a no-op for embed.)
    "fsdp": {**DEFAULT_RULES, "embed": (("data", "model"), "data"),
             "vocab": ("model", "data")},
}


def _mesh_sizes(mesh) -> dict[str, int]:
    # Works for jax.sharding.Mesh AND the duck-typed fake meshes in tests
    # (only axis_names + devices.shape are required).
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
                  mesh, rules: Optional[dict] = None) -> P:
    """Map logical axes to a PartitionSpec on ``mesh`` (see module doc)."""
    rules = DEFAULT_RULES if rules is None else rules
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    entries: list = []
    for dim, name in zip(shape, axes):
        entry = None
        for cand in rules.get(name, ()) if name else ():
            cand_axes = (cand,) if isinstance(cand, str) else tuple(cand)
            # drop mesh axes that don't exist, shard nothing (size 1), or
            # are already used — a size-1 axis kept inside a compound
            # prefix would yield non-canonical specs (("pod", "data") with
            # pod=1 instead of plain "data") and burn the axis via `used`
            cand_axes = tuple(a for a in cand_axes
                              if sizes.get(a, 1) > 1 and a not in used)
            if not cand_axes:
                continue
            # longest prefix whose size product divides the dimension
            for k in range(len(cand_axes), 0, -1):
                prefix = cand_axes[:k]
                prod = math.prod(sizes[a] for a in prefix)
                if prod > 1 and dim % prod == 0:
                    entry = prefix[0] if k == 1 else prefix
                    used.update(prefix)
                    break
            if entry is not None:
                break
        entries.append(entry)
    while entries and entries[-1] is None:   # trim for clean equality
        entries.pop()
    return P(*entries)


def scan_mesh_axes(mesh, rules: Optional[dict] = None) -> tuple[str, ...]:
    """Mesh axes the fused reader's split dimension shards over.

    Resolves the scan grid's logical "batch" axis against ``mesh`` with the
    same candidate rules as ``resolve_pspec`` (presets apply) but WITHOUT a
    divisibility test — the wave executor pads the split dimension up to
    the axis product itself.  Size-1 axes are dropped, so a (1, 1) host
    mesh yields ``()`` and callers fall back to the single-device path.
    """
    rules = DEFAULT_RULES if rules is None else rules
    sizes = _mesh_sizes(mesh)
    for cand in rules.get("batch", ()):
        cand_axes = (cand,) if isinstance(cand, str) else tuple(cand)
        cand_axes = tuple(a for a in cand_axes if sizes.get(a, 1) > 1)
        if cand_axes:
            return cand_axes
    return ()


def scan_device_count(mesh, axes: Sequence[str]) -> int:
    """Number of devices the scan grid spans on ``axes`` of ``mesh``."""
    sizes = _mesh_sizes(mesh)
    return int(math.prod(sizes[a] for a in axes)) if axes else 1


def named_sharding(spec: TensorSpec, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, resolve_pspec(spec.shape, spec.axes, mesh,
                                             rules))


def shardings(tree, mesh, rules=None):
    """TensorSpec tree -> NamedSharding tree (jit in/out_shardings)."""
    return map_specs(lambda s: named_sharding(s, mesh, rules), tree)


def shape_structs(tree, mesh, rules=None):
    """TensorSpec tree -> ShapeDtypeStruct tree with shardings attached
    (the dry-run's abstract arguments for jit.lower)."""
    return map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                       sharding=named_sharding(s, mesh,
                                                               rules)),
        tree)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _init_one(spec: TensorSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        # embedding tables: N(0, 1/d) on the model dim.  Embeddings may be
        # TIED to the unembed (lm_head = embed.T), so this keeps initial
        # logits near-uniform (loss ~ ln V); the sqrt(d) embed_scale on the
        # input side restores O(1) activations.
        d = spec.shape[-1]
        return (jax.random.normal(key, spec.shape) * 0.5 * d ** -0.5).astype(
            spec.dtype)
    scale = spec.scale
    if scale is None:
        fan_in = spec.shape[0] if spec.shape else 1
        scale = fan_in ** -0.5
    return (jax.random.normal(key, spec.shape) * scale).astype(spec.dtype)


def init_params(tree, key):
    """Initialize a pytree of arrays from a TensorSpec tree (one fold_in
    per leaf, path-stable)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    out = []
    for i, s in enumerate(leaves):
        out.append(_init_one(s, jax.random.fold_in(key, i)) if is_spec(s)
                   else s)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# In-trace constraints (sharding_ctx / constrain / ctx_axis_size)
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def sharding_ctx(mesh, rules=None):
    """Install (mesh, rules) for constrain() calls inside a traced fn."""
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, DEFAULT_RULES if rules is None else rules)
    try:
        yield
    finally:
        _CTX.state = prev


def ctx_axis_size(name: str) -> int:
    """Size of a mesh axis inside sharding_ctx (1 when absent/no ctx)."""
    state = getattr(_CTX, "state", None)
    if state is None:
        return 1
    return _mesh_sizes(state[0]).get(name, 1)


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint(x, resolved axes) — identity outside ctx."""
    state = getattr(_CTX, "state", None)
    if state is None:
        return x
    mesh, rules = state
    ps = resolve_pspec(x.shape, tuple(axes), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))
