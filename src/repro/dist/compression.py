"""bf16 gradient all-reduce with error feedback.

Gradients are quantized to bf16 on the wire (half the all-reduce bytes);
the quantization error is carried in a per-leaf fp32 residual and added
back before the next step's quantization, so the SUM of updates converges
to the true sum (error feedback, not error discard).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6
    from jax import shard_map
except ImportError:  # pinned 0.4.x
    from jax.experimental.shard_map import shard_map


def init_residual(grads):
    """Zero fp32 residual matching the gradient tree."""
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compressed_mean_grads(mesh, grads, residual, axis: str = "data"):
    """-> (mean_grads fp32, new_residual).  Mean over ``axis`` of ``mesh``
    with bf16 wire format + error feedback."""

    def local(g, r):
        t = g.astype(jnp.float32) + r
        wire = t.astype(jnp.bfloat16)
        mean = jax.lax.pmean(wire.astype(jnp.float32), axis)
        return mean, t - wire.astype(jnp.float32)

    def one(g, r):
        fn = shard_map(local, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()))
        return fn(g, r)

    flat, treedef = jax.tree.flatten(grads)
    rflat = jax.tree.leaves(residual)
    means, resids = [], []
    for g, r in zip(flat, rflat):
        m, nr = one(g, r)
        means.append(m)
        resids.append(nr)
    return jax.tree.unflatten(treedef, means), jax.tree.unflatten(treedef,
                                                                  resids)
