"""Distribution utilities: the logical-axis sharding resolver and the
bf16 gradient-compression collective."""
