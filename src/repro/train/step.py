"""Step builders: train_step / prefill_step / decode_step.

These are the functions the launcher jits (and the dry-run lowers).  Sharding
is injected two ways: (a) in_shardings/out_shardings computed from TensorSpec
trees, (b) internal with_sharding_constraint via the sharding_ctx installed
around tracing (see dist/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg, ShapeCfg
from repro.dist.sharding import (TensorSpec, init_params, map_specs,
                                 sharding_ctx, tspec)
from repro.models import model as model_mod
from repro.models.losses import chunked_xent, xent
from repro.models.model import decode_positions, forward, model_cache_specs, model_specs
from repro.train.optimizer import OptCfg, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class StepCfg:
    remat: str = "full"              # 'none' | 'full' | 'dots'
    loss: str = "plain"              # 'plain' | 'chunked'
    loss_chunks: int = 8
    donate_cache: bool = True


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — the dry-run contract)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelCfg, shape: ShapeCfg) -> dict[str, Any]:
    """TensorSpec tree for every model input of (arch x shape)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs: dict[str, Any] = {}
        if cfg.embed_inputs:
            specs["tokens"] = tspec((b, s), ("batch", "seq"), jnp.int32)
        else:  # vlm stub: precomputed patch/frame embeddings
            specs["inputs"] = tspec((b, s, cfg.d_model), ("batch", "seq", "act_embed"),
                                    jnp.bfloat16)
        if cfg.encoder is not None:  # whisper: frame embeddings + text tokens
            specs["tokens"] = tspec((b, s), ("batch", "seq"), jnp.int32)
            specs["enc_inputs"] = tspec((b, s, cfg.d_model),
                                        ("batch", "seq", "act_embed"), jnp.bfloat16)
        specs["labels"] = tspec((b, s), ("batch", "seq"), jnp.int32)
        return specs
    if shape.kind == "prefill":
        specs = {}
        if cfg.embed_inputs:
            specs["tokens"] = tspec((b, s), ("batch", "seq"), jnp.int32)
        else:
            specs["inputs"] = tspec((b, s, cfg.d_model), ("batch", "seq", "act_embed"),
                                    jnp.bfloat16)
        if cfg.encoder is not None:
            specs["tokens"] = tspec((b, s), ("batch", "seq"), jnp.int32)
            specs["enc_inputs"] = tspec((b, s, cfg.d_model),
                                        ("batch", "seq", "act_embed"), jnp.bfloat16)
        return specs
    if shape.kind == "decode":
        return {"tokens": tspec((b,), ("batch",), jnp.int32),
                "pos": tspec((), (), jnp.int32)}
    raise ValueError(shape.kind)


def cache_specs_for(cfg: ModelCfg, shape: ShapeCfg) -> dict[str, Any]:
    assert shape.kind == "decode"
    return model_cache_specs(cfg, shape.global_batch, shape.seq_len,
                             enc_len=min(shape.seq_len, 32768))


def train_state_specs(cfg: ModelCfg, opt: OptCfg) -> dict[str, Any]:
    p = model_specs(cfg)
    zero = lambda s: TensorSpec(s.shape, s.axes, opt.state_dtype, "zeros")
    return {"params": p,
            "m": map_specs(zero, p),
            "v": map_specs(zero, p),
            "step": tspec((), (), jnp.int32, init="zeros")}


def init_train_state(cfg: ModelCfg, opt: OptCfg, key):
    params = init_params(model_specs(cfg), key)
    st = init_opt_state(params, opt)
    return {"params": params, "m": st["m"], "v": st["v"], "step": st["step"]}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelCfg, opt: OptCfg, step_cfg: StepCfg = StepCfg(),
                    mesh=None, rules=None):
    def train_step(state, batch):
        with sharding_ctx(mesh, rules) if mesh is not None else _null():
            def loss_fn(params):
                inputs = batch.get("tokens") if cfg.embed_inputs else batch["inputs"]
                kw = {}
                if cfg.encoder is not None:
                    kw["enc_inputs"] = batch["enc_inputs"]
                    inputs = batch["tokens"]
                if step_cfg.loss == "chunked":
                    hidden = forward(params, cfg, inputs, mode="train",
                                     remat=step_cfg.remat, return_hidden=True,
                                     **kw)
                    head = model_mod.lm_head(params, cfg).astype(hidden.dtype)
                    return chunked_xent(hidden, head, batch["labels"],
                                        step_cfg.loss_chunks)
                logits = forward(params, cfg, inputs, mode="train",
                                 remat=step_cfg.remat, **kw)
                return xent(logits, batch["labels"])

            loss, grads = jax.value_and_grad(loss_fn)(state["params"])
            new_p, new_opt, metrics = adamw_update(
                state["params"], grads,
                {"m": state["m"], "v": state["v"], "step": state["step"]}, opt)
            metrics["loss"] = loss
            new_state = {"params": new_p, "m": new_opt["m"], "v": new_opt["v"],
                         "step": new_opt["step"]}
            return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelCfg, step_cfg: StepCfg = StepCfg(),
                      mesh=None, rules=None, max_len: int | None = None):
    """max_len: KV-cache capacity for subsequent decode steps (defaults to
    the prompt length — pass prompt+generation budget when serving)."""
    def prefill_step(params, batch):
        with sharding_ctx(mesh, rules) if mesh is not None else _null():
            inputs = batch.get("tokens") if cfg.embed_inputs else batch["inputs"]
            kw = {}
            if cfg.encoder is not None:
                kw["enc_inputs"] = batch["enc_inputs"]
                inputs = batch["tokens"]
            logits, cache = forward(params, cfg, inputs, mode="prefill",
                                    cache_len=max_len, **kw)
            return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg: ModelCfg, step_cfg: StepCfg = StepCfg(),
                     mesh=None, rules=None):
    from repro.models.model import _mrope

    def decode_step(params, cache, batch):
        with sharding_ctx(mesh, rules) if mesh is not None else _null():
            tokens = batch["tokens"][:, None]                 # (B,1)
            pos = decode_positions(batch["pos"], tokens.shape[0], _mrope(cfg))
            logits, cache = forward(params, cfg, tokens, mode="decode",
                                    cache=cache, positions=pos)
            return logits[:, 0], cache

    return decode_step


import contextlib


@contextlib.contextmanager
def _null():
    yield
