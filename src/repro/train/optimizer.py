"""AdamW + global-norm clipping + LR schedules, built from scratch (no optax).

Optimizer state dtype is configurable: fp32 (default) or bf16 moments —
the bf16 option halves optimizer HBM (a §Perf memory-term lever for
arctic-480b / qwen2-vl-72b scale).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    state_dtype: Any = jnp.float32     # bf16 halves optimizer memory


def lr_at(cfg: OptCfg, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptCfg):
    z = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: OptCfg):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p32 = p32 - lr * (step_ + wd * p32)
        return (p32.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step + 1}, metrics
