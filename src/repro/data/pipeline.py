"""HailDataSource: the paper's data plane feeding the LM training loop.

A tokenized corpus lives in the HAIL block store (selection attributes +
token payload columns, see schema.tokens_schema).  Training-data selection
("train on domain=3", "quality >= 900") becomes an indexed HAIL query: the
planner routes to the replica clustered on the filter attribute, the record
reader touches only qualifying partitions, and the loader assembles
fixed-shape (batch, seq) token matrices — exploratory data-selection sweeps
(Bob's workflow, applied to curriculum/quality filtering) go from full-corpus
scans to index scans.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.store import BlockStore


@dataclasses.dataclass
class CorpusConfig:
    n_docs: int = 4096
    seq_width: int = 128          # tokens per document row
    rows_per_block: int = 1024
    vocab: int = 50_000
    n_domains: int = 16
    replication_keys: tuple = ("domain", "quality", "timestamp")
    partition_size: int = 256


def build_corpus(cfg: CorpusConfig, seed: int = 0) -> tuple[BlockStore, up.UploadStats]:
    """Generate + HAIL-upload a tokenized corpus."""
    from repro.core.parse import format_rows

    schema = sc.tokens_schema(cfg.seq_width)
    cols = sc.gen_tokens_corpus(cfg.n_docs, cfg.seq_width, cfg.vocab,
                                cfg.n_domains, seed)
    enc = format_rows(schema, cols)
    n_blocks = cfg.n_docs // cfg.rows_per_block
    raw = enc.reshape(n_blocks, cfg.rows_per_block, -1)
    return up.hail_upload(schema, raw, list(cfg.replication_keys),
                          cfg.partition_size)


class HailDataSource:
    """Iterator of token batches selected by a HAIL query."""

    def __init__(self, store: BlockStore, cfg: CorpusConfig,
                 select: Optional[tuple[str, int, int]] = None,
                 batch_size: int = 8, seq_len: Optional[int] = None,
                 seed: int = 0):
        self.store = store
        self.cfg = cfg
        self.batch = batch_size
        self.seq = seq_len or cfg.seq_width
        assert self.seq <= cfg.seq_width
        query = q.HailQuery(filter=select,
                            projection=tuple(f"tok{i}" for i in range(self.seq)))
        qplan = q.plan(store, query)
        self.used_index = bool(qplan.index_scan.all()) and select is not None
        res = q.read_hail(store, query, qplan)
        rows = q.collect(res)
        toks = np.stack([rows[f"tok{i}"] for i in range(self.seq)], axis=1)
        self.tokens = toks.astype(np.int32)      # (n_selected, seq)
        self.rng = np.random.default_rng(seed)

    @property
    def n_selected(self) -> int:
        return self.tokens.shape[0]

    def __iter__(self) -> Iterator[dict]:
        assert self.n_selected >= self.batch, "selection smaller than batch"
        while True:
            idx = self.rng.integers(0, self.n_selected, self.batch)
            t = self.tokens[idx]
            yield {"tokens": jnp.asarray(t[:, :-1]),
                   "labels": jnp.asarray(t[:, 1:])}
