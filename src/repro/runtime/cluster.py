"""Simulated cluster: nodes, heartbeats, failures, stragglers.

The CPU container cannot run 1000 nodes, but the *scheduling control plane*
can be exercised for real: this event-driven simulator drives the same task
scheduler that the HailSplitting benchmarks use, with per-node speed factors
(stragglers), fail-stop node deaths detected by heartbeat expiry (the
paper's 30s expiry in §6.4.3), and replica-aware rescheduling.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class NodeState:
    node_id: int
    speed: float = 1.0            # task runtime multiplier (>1 = straggler)
    alive: bool = True
    last_heartbeat: float = 0.0


class SimulatedCluster:
    def __init__(self, n_nodes: int, map_slots: int = 4, seed: int = 0,
                 straggler_frac: float = 0.0, straggler_slow: float = 4.0,
                 heartbeat_expiry_s: float = 30.0):
        rng = np.random.default_rng(seed)
        self.nodes = [NodeState(i) for i in range(n_nodes)]
        n_strag = int(round(straggler_frac * n_nodes))
        for i in rng.choice(n_nodes, n_strag, replace=False):
            self.nodes[i].speed = straggler_slow
        self.map_slots = map_slots
        self.heartbeat_expiry_s = heartbeat_expiry_s
        self._fail_at: dict[int, float] = {}

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def schedule_failure(self, node_id: int, at_time_s: float):
        self._fail_at[node_id] = at_time_s

    def tick(self, now_s: float) -> list[int]:
        """Advance liveness; returns nodes newly detected dead (heartbeat
        expiry after their fail time)."""
        newly_dead = []
        for nid, t_fail in list(self._fail_at.items()):
            node = self.nodes[nid]
            if node.alive and now_s >= t_fail + self.heartbeat_expiry_s:
                node.alive = False
                newly_dead.append(nid)
        return newly_dead

    def is_failed(self, node_id: int, now_s: float) -> bool:
        """True once the node has actually died (even if not yet detected)."""
        t = self._fail_at.get(node_id)
        return t is not None and now_s >= t

    def alive_nodes(self) -> list[int]:
        return [n.node_id for n in self.nodes if n.alive]
