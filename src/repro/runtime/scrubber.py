"""Budgeted background scrubber: find corruption before queries do.

HDFS's DataBlockScanner walks every datanode's blocks in the background and
re-verifies their checksums so silent disk rot is caught long before a
client read trips over it.  This is the repro's analogue for the HAIL
store: a ``Scrubber`` attached to a ``BlockStore`` verifies a bounded batch
of (replica, block) pairs per ``tick()`` — ``run_job`` and
``HailServer.flush`` tick it at their job/flush boundaries, so scrubbing
rides the cluster's natural idle points instead of competing with the read
path — and immediately repairs whatever the tick (or earlier read-path
detection) quarantined, via ``BlockStore.repair_blocks``.

The scan order is a persistent round-robin cursor over all (replica, block)
pairs: every pair is re-verified once per full revolution regardless of
query traffic, which is exactly the coverage guarantee hot-path
verification cannot give (reads only verify what queries touch, and the
BlockCache means even that only on fills).  Verification reuses
``BlockStore.verify_block`` — all columns' chunk checksums plus
root-directory consistency for indexed blocks — so the scrubber detects
every fault class the read path does, including stale root directories on
blocks no query has ranged over yet.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core.store import BlockStore
from repro.obs import trace as obs_trace


@dataclasses.dataclass(frozen=True)
class ScrubConfig:
    """``blocks_per_tick``: verification budget per job/flush boundary (the
    scrub tax a single job tolerates).  ``repair``: rebuild quarantined
    blocks from healthy replicas at the end of the tick."""
    blocks_per_tick: int = 8
    repair: bool = True


@dataclasses.dataclass
class ScrubStats:
    """Cumulative over the scrubber's lifetime."""
    ticks: int = 0
    blocks_verified: int = 0
    blocks_quarantined: int = 0
    blocks_repaired: int = 0
    unrepairable: int = 0
    bytes_rewritten: int = 0
    wall_s: float = 0.0


class Scrubber:
    """Round-robin verifier + repairer for one PAX ``BlockStore``."""

    def __init__(self, store: BlockStore,
                 config: ScrubConfig = ScrubConfig()):
        assert store.layout == "pax", "the scrubber targets PAX stores"
        self.store = store
        self.config = config
        self.stats = ScrubStats()
        self._cursor = 0

    def attach(self) -> "Scrubber":
        """Install on the store — ``run_job``/``flush`` tick
        ``store.scrubber`` at their boundaries."""
        self.store.scrubber = self
        return self

    def _schedule(self) -> list[tuple[int, int]]:
        """Next ``blocks_per_tick`` (replica, block) pairs under the
        persistent cursor, skipping dead nodes (nothing to read) and
        already-quarantined blocks (known bad; repair handles them)."""
        store = self.store
        pairs = [(r, b) for r in store.live_replica_ids()
                 for b in range(store.n_blocks)]
        if not pairs:
            return []
        out = []
        for k in range(len(pairs)):
            if len(out) >= self.config.blocks_per_tick:
                break
            rid, b = pairs[(self._cursor + k) % len(pairs)]
            node = int(store.replicas[rid].nodes[b])
            if (node in store.namenode.dead
                    or store.namenode.is_quarantined(b, node)):
                continue
            out.append((rid, b))
        self._cursor = (self._cursor + self.config.blocks_per_tick) \
            % len(pairs)
        return out

    def tick(self):
        """One scrub quantum: verify the scheduled pairs, quarantine
        failures, then repair everything quarantined (including blocks the
        READ PATH quarantined since the last tick).  Returns the
        cumulative ``ScrubStats``."""
        t0 = time.perf_counter()
        store = self.store
        self.stats.ticks += 1
        verified = quarantined = repaired = 0
        for rid, b in self._schedule():
            self.stats.blocks_verified += 1
            verified += 1
            if not store.verify_block(rid, b):
                store.quarantine_block(rid, b)
                self.stats.blocks_quarantined += 1
                quarantined += 1
                obs_trace.instant("scrub_quarantine", track="scrubber",
                                  args={"replica": rid, "block": b})
        if self.config.repair and store.namenode.quarantined:
            t_r = time.perf_counter()
            rs = store.repair_blocks()
            self.stats.blocks_repaired += rs.blocks_repaired
            self.stats.unrepairable += rs.unrepairable
            self.stats.bytes_rewritten += rs.bytes_rewritten
            repaired = rs.blocks_repaired
            obs_trace.complete_wall("repair", t_r,
                                    time.perf_counter() - t_r,
                                    track="scrubber",
                                    args={"repaired": rs.blocks_repaired,
                                          "unrepairable": rs.unrepairable})
        self.stats.wall_s += time.perf_counter() - t0
        obs_trace.complete_wall("scrub_tick", t0,
                                time.perf_counter() - t0, track="scrubber",
                                args={"cursor": self._cursor,
                                      "verified": verified,
                                      "quarantined": quarantined,
                                      "repaired": repaired})
        return self.stats
