"""Event-driven task scheduler with speculative execution (stragglers) and
replica-aware failover — the JobTracker analogue for the simulated cluster.

Semantics implemented (and benchmarked in bench_failover / tests):
  * data-locality-first placement: a task prefers its replica nodes
    (namenode Dir_block), falling back to any free slot;
  * fail-stop nodes: tasks running on a node that dies are re-queued once
    the heartbeat expiry detects the death (paper §6.4.3's 30s);
  * speculative re-execution: when a running task exceeds
    ``spec_factor x`` the median completed duration, a duplicate launches on
    a different node; first finisher wins (straggler mitigation).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

from repro.runtime.cluster import SimulatedCluster


@dataclasses.dataclass
class Task:
    task_id: int
    duration_s: float                   # nominal duration on a speed-1 node
    preferred_nodes: tuple[int, ...]    # replica locations
    index_build_s: float = 0.0          # adaptive indexing piggybacked on
    #   this map task (JobStats.build_s) — charged into the task's runtime
    #   so convergence-era tasks are honestly slower in the simulation
    rekey_s: float = 0.0                # governor demotion (un-sort +
    #   re-checksum of an evicted replica) triggered by this task
    #   (JobStats.demote_s) — charged the same way as index builds
    n_queries: int = 1                  # queries served by this task: a
    #   HailServer shared-scan task answers a whole batch with one fused
    #   dispatch, so Q rides on one task's scheduling overhead —
    #   ScheduleResult.n_query_answers totals these (query, split) answers
    #   across the schedule (distinct-query throughput is the caller's to
    #   compute: bench_server divides Q by the makespan)
    query_ids: tuple[int, ...] = ()     # the distinct queries whose answers
    #   DEPEND on this task (a shared-scan split carries the batch members
    #   it is live for) — run_schedule folds these into per-query
    #   completion timestamps (ScheduleResult.query_completion_s), the
    #   latency signal the ServerFrontend's SLO accounting consumes


@dataclasses.dataclass
class TaskRun:
    task_id: int
    node: int
    start_s: float
    end_s: float
    speculative: bool = False


@dataclasses.dataclass
class ScheduleResult:
    makespan_s: float
    runs: list[TaskRun]
    n_speculative: int
    n_failovers: int
    locality_fraction: float
    n_query_answers: int = 0            # total (query, split) answers the
    #   tasks produced — NOT distinct queries (a Q-wide batch over S splits
    #   counts Q*S), so dividing by makespan gives answer throughput; for
    #   query throughput divide the caller's distinct-query count instead
    query_completion_s: dict = dataclasses.field(default_factory=dict)
    #   query id -> simulated time its LAST carrying task finished (a query
    #   streams back the moment every split it depends on has completed,
    #   not at the schedule's end) — queries carried by no task (e.g.
    #   result-cache hits, fully pruned ranges) complete at time 0


def run_schedule(tasks: list[Task], cluster: SimulatedCluster,
                 spec_factor: Optional[float] = 1.8) -> ScheduleResult:
    """Simulate executing `tasks` to completion. Returns timing stats."""
    slots: dict[int, int] = {n.node_id: cluster.map_slots for n in cluster.nodes}
    queue = list(tasks)
    running: list[tuple[float, int, TaskRun]] = []   # heap by end time
    done: dict[int, TaskRun] = {}
    durations: list[float] = []
    now = 0.0
    n_spec = n_failover = local_hits = assignments = 0
    seq = 0
    launched_spec: set[int] = set()

    def launch(task: Task, speculative: bool, avoid: Optional[int] = None):
        nonlocal seq, local_hits, assignments
        alive = [n for n in cluster.alive_nodes()
                 if slots[n] > 0 and n != avoid and not cluster.is_failed(n, now)]
        if not alive:
            return False
        pref = [n for n in task.preferred_nodes if n in alive]
        node = pref[0] if pref else alive[seq % len(alive)]
        if pref:
            local_hits += 1
        assignments += 1
        seq += 1
        slots[node] -= 1
        speed = cluster.nodes[node].speed
        work_s = task.duration_s + task.index_build_s + task.rekey_s
        run = TaskRun(task.task_id, node, now, now + work_s * speed,
                      speculative=speculative)
        heapq.heappush(running, (run.end_s, seq, run))
        return True

    task_by_id = {t.task_id: t for t in tasks}
    # initial fill
    pending = list(queue)
    progressed = True
    while pending or running:
        # launch as many pending as possible
        still = []
        for t in pending:
            if t.task_id in done:
                continue
            if not launch(t, speculative=False):
                still.append(t)
        pending = still

        if not running:
            if pending:
                # all nodes busy/dead: advance detection clock
                now += cluster.heartbeat_expiry_s
                cluster.tick(now)
                continue
            break

        end_s, _, run = heapq.heappop(running)
        now = max(now, end_s)
        cluster.tick(now)

        if cluster.is_failed(run.node, now):
            # node died mid-task: requeue after detection
            if run.task_id not in done:
                n_failover += 1
                t = task_by_id[run.task_id]
                now = max(now, cluster._fail_at[run.node]
                          + cluster.heartbeat_expiry_s)
                cluster.tick(now)
                pending.append(t)
            continue

        slots[run.node] += 1
        if run.task_id not in done:
            done[run.task_id] = run
            durations.append(run.end_s - run.start_s)

        # speculative launch check for the slowest running tasks
        if spec_factor is not None and durations:
            med = sorted(durations)[len(durations) // 2]
            for (e, _, r) in list(running):
                if (r.task_id not in done and r.task_id not in launched_spec
                        and (e - r.start_s) > spec_factor * med):
                    if launch(task_by_id[r.task_id], speculative=True,
                              avoid=r.node):
                        launched_spec.add(r.task_id)
                        n_spec += 1

    makespan = max((r.end_s for r in done.values()), default=0.0)
    completion: dict = {}
    for run in done.values():
        for qid in task_by_id[run.task_id].query_ids:
            completion[qid] = max(completion.get(qid, 0.0), run.end_s)
    return ScheduleResult(
        makespan_s=makespan, runs=list(done.values()), n_speculative=n_spec,
        n_failovers=n_failover,
        locality_fraction=local_hits / max(assignments, 1),
        n_query_answers=sum(t.n_queries for t in tasks),
        query_completion_s=completion)
