"""HailServer: concurrent multi-query serving over one HAIL block store.

``run_job`` executes exactly one query at a time; the north star is a
system serving heavy concurrent traffic, where that model re-reads the
same hot blocks for every caller and lets every tenant trigger its own
adaptive index builds.  The server closes the gap with three mechanisms:

* **Admission control** — ``submit`` enforces per-tenant and global queue
  quotas and REJECTS over-quota submissions (``AdmissionError``):
  back-pressure at the door instead of unbounded queue growth, so one hot
  tenant cannot starve the rest.

* **Shared-scan batching** — ``flush`` groups compatible pending queries
  (same filter column, same projection — hence the same replica plan) into
  batches of ``max_batch`` and dispatches each batch as ONE fused Pallas
  call per split (``query.read_hail_batch``: the runtime ``(Q, 2)`` lo/hi
  array rides in SMEM, the kernel emits per-query match masks), so Q
  concurrent range queries over a split cost one dispatch and one pass
  over the data instead of Q.  Row-sets are identical to Q serial
  ``run_job`` calls — including under mid-batch demotion and node failure
  (the same re-plan/retry path ``run_job`` uses, exercised per batch).

* **A governor-integrated hot-block cache** — decoded per-split reader
  inputs live in a capacity-bounded, SCAN-RESISTANT segmented cache
  (``core/cache.BlockCache``) attached to the store; hits skip the
  host-side gather entirely, misses fill it, the store's destructive
  transitions (``commit_block_indexes``, ``demote_replica``,
  ``quarantine_block``, ``repair_blocks``) invalidate the touched
  replica's entries, and every read — cached or not — is still attributed
  per query into the ``AccessLog``, so the IndexGovernor's LRU signal
  sees cached traffic.

* **A query-result cache** — the second tier (``core/cache.ResultCache``):
  materialized answers keyed (filter col, lo, hi, projection, store
  version).  ``flush`` first tries to serve each pending query from it —
  a repeated (or subsumed, when the filter column is projected) range
  skips batching, planning and the fused scan entirely, with ZERO reader
  dispatches — and replays the entry's fill-time attribution recipe
  through ``governor.attribute_read``, so a hot-but-result-cached index
  never looks LRU-cold to the governor.  Every destructive store
  transition bumps ``BlockStore.version`` and drops the tier, so a stale
  answer is structurally unreachable.

Adaptive builds are budgeted at the WORKLOAD level ("Towards Zero-Overhead
Adaptive Indexing" argues the build budget belongs to the workload, not
the job): one ``offer_rate`` quantum is drawn per flush
(``mapreduce.adaptive_quantum``) and shared by every batch in submission
order — eight concurrent tenants advance convergence by one job's worth,
not eight.

``ServerFrontend`` puts an ASYNC, latency-SLO event loop on top: callers
``offer`` queries with simulated arrival times and a ``FlushPolicy`` decides
when flushes fire — when the OLDEST pending query has waited ``window_s``
(the SLO knob) or a compatible batch fills to ``max_batch`` — instead of a
caller-driven ``flush()`` being the only trigger (``flush`` stays, for tests
and for the frontend's own cycles).  Per-query answers STREAM back as the
last split each query depends on completes (``FlushStats.query_done_s``, and
the scheduler bridge's ``query_completion_s``), not at a flush-end barrier;
and when pending work exceeds one flush's capacity, weighted-fair admission
(per-tenant virtual time) decides which batches dispatch first.

Each FLUSH is one job boundary for the governor (``note_job_start``) —
the flush is the user-visible workload unit, so claim-time eviction
hysteresis applies to server traffic exactly as to serial jobs: a column
seen for the first time cannot satisfy the threshold with its own flush's
batches.  The scheduler bridge (``flush_tasks``) turns a flush into
``runtime/scheduler.Task``s whose ``n_queries`` records the batch width —
one task's scheduling overhead amortized over Q answers is the serving
analogue of HailSplitting's fewer-map-tasks win; ``bench_server``'s guard
compares the resulting makespans (and distinct-query throughput,
Q / makespan) between the batched and serial schedules.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core import governor as gvn
from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core.cache import BlockCache, ResultCache
from repro.core.fault import (CorruptBlockError, RecoveryConfig,
                              UnrecoverableDataError)
from repro.core.query import HailQuery
from repro.core.schema import ROWID
from repro.core.splitting import Split, hadoop_splits, hail_splits
from repro.core.store import BlockStore
from repro.obs import explain as obs_explain
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime.cluster import SimulatedCluster
from repro.runtime.scheduler import Task, run_schedule


class AdmissionError(RuntimeError):
    """Submission rejected: the tenant (or the whole server) is over its
    pending-query quota."""


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Serving knobs.

    ``max_batch``: widest shared-scan batch (Q) per fused dispatch — also
    the only extra compile dimension (one reader variant per distinct batch
    width, reused forever after).  ``max_pending_per_tenant`` /
    ``max_pending_total``: admission-control quotas enforced by ``submit``.
    ``cache_bytes``: hot-block cache capacity (None = unbounded;
    ``cache=False`` disables caching entirely).  ``result_cache`` /
    ``result_cache_bytes``: the materialized-answer tier, same knob shape
    (benches that measure the scan path itself disable it).  ``adaptive``:
    when set, flushes draw ONE shared build quantum (see module docstring).
    ``mesh``: a ``jax.sharding.Mesh`` to SHARD each batch's fused scan
    over — splits gather host-side as usual but dispatch in WAVES of up to
    n_dev splits through one shard_map'd fused call (see
    ``mapreduce.run_job``); meshes without a multi-device scan axis fall
    back to the serial per-split dispatch.
    """
    max_batch: int = 8
    max_pending_per_tenant: int = 8
    max_pending_total: int = 64
    reader: str = "kernels"
    mesh: Optional[object] = None
    cache: bool = True
    cache_bytes: Optional[int] = None
    result_cache: bool = True
    result_cache_bytes: Optional[int] = None
    adaptive: Optional[mr.AdaptiveConfig] = None
    cluster: mr.ClusterModel = dataclasses.field(
        default_factory=mr.ClusterModel)
    recovery: RecoveryConfig = dataclasses.field(
        default_factory=RecoveryConfig)


@dataclasses.dataclass
class QueryResult:
    """Materialized answer for one submitted query."""
    n_rows: int
    rows: dict[str, np.ndarray]    # projection (+__rowid__) of matching rows
    batch_size: int                # Q of the shared-scan batch that served it
    n_splits: int                  # fused dispatches that batch issued
    from_cache: bool = False       # served by the result cache (no scan)


@dataclasses.dataclass
class Ticket:
    ticket_id: int
    tenant: str
    query: HailQuery
    status: str = "queued"         # queued -> done | failed
    result: Optional[QueryResult] = None
    error: Optional[str] = None    # typed terminal failure (retry budget
    #   exhausted mid-flush) — set alongside status="failed", never silently
    #   stranded "queued"
    explain_ctx: Optional[object] = None   # shared per-flush EXPLAIN
    #   context (obs.explain.FlushExplain), attached by the flush that
    #   answered this ticket; resolved lazily by ``explain()``

    def explain(self):
        """Reconstruct why this query took the time it did — queue wait vs
        service, flush trigger, per-split scan modes, cache-tier outcome,
        retries survived, build/demotion walls charged.  Returns an
        ``obs.explain.ExplainRecord`` (render with ``str()``); raises if
        the ticket has not been through a flush yet."""
        return obs_explain.explain_ticket(self)


@dataclasses.dataclass
class FlushStats:
    """One ``flush``: every pending query answered."""
    n_queries: int
    n_batches: int
    n_splits: int                  # fused dispatches == (split, batch) pairs
    batch_sizes: list
    blocks_indexed: int = 0        # shared adaptive quantum actually spent
    blocks_demoted: int = 0
    rescheduled_tasks: int = 0
    bytes_read: int = 0            # PHYSICAL shared-scan bytes (union range)
    split_s: list = dataclasses.field(default_factory=list)
    build_s: list = dataclasses.field(default_factory=list)
    demote_s: list = dataclasses.field(default_factory=list)
    batch_of_split: list = dataclasses.field(default_factory=list)
    # ^ batch width (Q) per executed split, aligned with split_s — the
    #   scheduler bridge stamps it into Task.n_queries
    queries_of_split: list = dataclasses.field(default_factory=list)
    # ^ ticket ids whose answer DEPENDS on each executed split (its LIVE
    #   members: key-range overlap, or any full-scan block), aligned with
    #   split_s — the scheduler bridge stamps them into Task.query_ids so
    #   run_schedule can emit per-query completion timestamps
    split_scan_modes: list = dataclasses.field(default_factory=list)
    # ^ (index_blocks, full_scan_blocks) per executed split, aligned with
    #   split_s — per-query scan-mode attribution for ``Ticket.explain()``
    query_done_s: dict = dataclasses.field(default_factory=dict)
    # ^ ticket id -> wall seconds after flush start when its answer
    #   FINALIZED (streamed back) — result-cache hits and fully-pruned
    #   queries land near 0, batch members do not wait for the flush end
    failed_queries: list = dataclasses.field(default_factory=list)
    # ^ ticket ids terminally failed this flush (typed, not stranded)
    demote_residue_s: float = 0.0  # demotion wall charged at claim time but
    #   not carried by any executed split (every split after the claim was
    #   pruned or re-planned away) — flushed here so the scheduler bridge
    #   never undercharges
    cache_hits: int = 0            # this flush's block-cache traffic
    cache_misses: int = 0
    result_cache_hits: int = 0     # queries answered without any scan
    result_cache_misses: int = 0
    wall_s: float = 0.0
    modeled_s: float = 0.0         # deterministic: scheduling + shared disk
    blocks_quarantined: int = 0    # corrupt (replica, block)s this flush found
    corrupt_retries: int = 0       # batch splits re-planned after corruption
    scrub_s: float = 0.0           # boundary scrub wall (verify + repair)


def flush_tasks(stats: FlushStats) -> list[Task]:
    """Bridge a flush into the event-driven cluster simulator: one Task per
    executed (split, batch), duration = measured read wall, piggybacked
    build/demotion walls charged like ``mapreduce.job_tasks``, and the batch
    width recorded in ``Task.n_queries`` (totaled by ``run_schedule`` as
    ``ScheduleResult.n_query_answers`` — (query, split) answers, from which
    callers derive throughput against their distinct-query count).  Each
    task also carries the ticket ids live on its split (``Task.query_ids``),
    so ``run_schedule`` yields per-query completion timestamps — the
    ServerFrontend's latency signal.  Demotion wall not carried by any
    executed split (``demote_residue_s``) is charged to the first task, or
    to a synthetic zero-duration task when the flush executed none."""
    qids = stats.queries_of_split or [()] * len(stats.split_s)
    tasks = [Task(i, dur, preferred_nodes=(), index_build_s=build,
                  rekey_s=rekey, n_queries=nq, query_ids=tuple(qq))
             for i, (dur, build, rekey, nq, qq)
             in enumerate(zip(stats.split_s, stats.build_s, stats.demote_s,
                              stats.batch_of_split, qids))]
    if stats.demote_residue_s:
        if tasks:
            tasks[0].rekey_s += stats.demote_residue_s
        else:
            tasks.append(Task(0, 0.0, preferred_nodes=(),
                              rekey_s=stats.demote_residue_s, n_queries=0))
    return tasks


class HailServer:
    """Multi-tenant serving frontend over one ``BlockStore``.

    ``submit`` enqueues (admission-controlled); ``flush`` answers every
    pending query via shared-scan batches.  The split between the two is
    the batching window: everything submitted since the last flush is
    eligible to share scans.
    """

    def __init__(self, store: BlockStore, config: ServerConfig = None):
        self.store = store
        self.config = config or ServerConfig()
        self.tickets: list[Ticket] = []        # completed + queued (by id)
        self._pending: list[Ticket] = []
        self.cache: Optional[BlockCache] = None
        if self.config.cache:
            # an EXPLICIT capacity always wins: if the store already carries
            # a cache with a different budget, attach a fresh one at the
            # requested size (silently inheriting an unbounded cache would
            # make the configured budget a no-op); cache_bytes=None reuses
            # whatever is attached, else attaches unbounded
            existing = store.block_cache
            if existing is None or (
                    self.config.cache_bytes is not None
                    and existing.capacity_bytes != self.config.cache_bytes):
                existing = BlockCache(self.config.cache_bytes).attach(store)
            self.cache = existing
        self.result_cache: Optional[ResultCache] = None
        if self.config.result_cache:
            existing_rc = store.result_cache
            if existing_rc is None or (
                    self.config.result_cache_bytes is not None
                    and existing_rc.capacity_bytes
                    != self.config.result_cache_bytes):
                existing_rc = ResultCache(
                    self.config.result_cache_bytes).attach(store)
            self.result_cache = existing_rc

    # -- admission ----------------------------------------------------------

    def pending_count(self, tenant: Optional[str] = None) -> int:
        if tenant is None:
            return len(self._pending)
        return sum(1 for t in self._pending if t.tenant == tenant)

    def submit(self, query: HailQuery, tenant: str = "default") -> Ticket:
        """Enqueue one query for the next flush; rejects over quota."""
        if self.pending_count() >= self.config.max_pending_total:
            raise AdmissionError(
                f"server queue full ({self.config.max_pending_total})")
        if self.pending_count(tenant) >= self.config.max_pending_per_tenant:
            raise AdmissionError(
                f"tenant {tenant!r} over quota "
                f"({self.config.max_pending_per_tenant} pending)")
        t = Ticket(ticket_id=len(self.tickets), tenant=tenant, query=query)
        self.tickets.append(t)
        self._pending.append(t)
        return t

    # -- batching -----------------------------------------------------------

    def _batches(self, tickets: Sequence[Ticket]) -> list[list[Ticket]]:
        """Group compatible queries — same (filter column, projection) means
        same replica plan and one shared scan — into chunks of
        ``max_batch``, preserving submission order within a group.  Queries
        without a filter cannot share a scan and run as singletons."""
        groups: dict = {}
        for t in tickets:
            if t.query.filter is None or self.store.layout != "pax":
                key = ("__single__", t.ticket_id)
            else:
                key = (t.query.filter_col, tuple(t.query.projection))
            groups.setdefault(key, []).append(t)
        out = []
        for members in groups.values():
            for i in range(0, len(members), self.config.max_batch):
                out.append(members[i:i + self.config.max_batch])
        return out

    # -- execution ----------------------------------------------------------

    def flush(self, fail_node_at: Optional[float] = None) -> FlushStats:
        """Answer every pending query.

        ``fail_node_at``: failure-injection fraction (of the first batch's
        splits), the same knob ``run_job`` exposes — the killed node stays
        dead for the REST of the flush (later batches plan around it) and
        is revived at the end, so one flush exercises both the mid-batch
        retry path and cross-batch re-planning.
        """
        tickets, self._pending = self._pending, []
        # ONE governor job boundary per flush (not per batch): the flush is
        # the user-visible workload unit, so a never-before-seen column
        # cannot satisfy claim-time hysteresis with its own batches —
        # "queries once" means "one flush", however many batches it takes.
        # Opened BEFORE the result-cache short-circuit so replayed
        # attribution lands in this job, like the scans it stands in for.
        gvn.note_job_start(self.store)
        rc = self.result_cache
        rc_h0 = rc.stats.hits if rc else 0
        rc_m0 = rc.stats.misses if rc else 0
        t0 = time.perf_counter()
        # tier 2 first: a repeated/subsumed range skips batching, planning
        # and the fused scan entirely — only the misses get batched below
        with obs_trace.span("result_cache_probe", track="server",
                            args={"queries": len(tickets)}):
            missed = [t for t in tickets
                      if not self._serve_from_result_cache(t)]
        with obs_trace.span("batching", track="server"):
            batches = self._batches(missed)
        stats = FlushStats(n_queries=len(tickets), n_batches=len(batches),
                           n_splits=0,
                           batch_sizes=[len(b) for b in batches])
        for t in tickets:
            if t.status == "done":     # result-cache hit: streamed at ~0
                stats.query_done_s[t.ticket_id] = time.perf_counter() - t0
        cache_h0 = self.cache.stats.hits if self.cache else 0
        cache_m0 = self.cache.stats.misses if self.cache else 0
        # ONE shared adaptive quantum for the whole flush: concurrent
        # tenants advance convergence by one job's worth, not Q jobs' worth
        budget = {"left": 0}
        if self.config.adaptive is not None and self.store.layout == "pax":
            budget["left"] = mr.adaptive_quantum(self.store,
                                                 self.config.adaptive)
        fail = {"frac": fail_node_at, "node": None}
        # corruption retry budget is per FLUSH per block — corruption and
        # node-failure retries share it, like run_job's
        retries: collections.Counter = collections.Counter()
        try:
            for batch in batches:
                t_b = time.perf_counter()
                try:
                    self._run_batch(batch, stats, budget, fail, retries, t0)
                except UnrecoverableDataError as e:
                    # the failed batch terminates TYPED — its not-yet-
                    # finalized tickets get status="failed" (never stranded
                    # "queued") and the remaining batches still run
                    for t in batch:
                        if t.status != "done":
                            t.status = "failed"
                            t.error = str(e)
                            stats.failed_queries.append(t.ticket_id)
                    # splits dispatched but never barriered leave the
                    # per-split lists longer than split_s; realign so the
                    # scheduler bridge's zip cannot silently drop their
                    # demotion wall (build wall is dropped with the batch —
                    # the claim-time demotion mutated the store, the builds
                    # answered nothing)
                    extra = len(stats.demote_s) - len(stats.split_s)
                    if extra > 0:
                        stats.demote_residue_s += sum(stats.demote_s[-extra:])
                        del stats.demote_s[-extra:]
                        del stats.build_s[-extra:]
                        del stats.batch_of_split[-extra:]
                        del stats.queries_of_split[-extra:]
                        del stats.split_scan_modes[-extra:]
                finally:
                    obs_trace.complete_wall(
                        "batch", t_b, time.perf_counter() - t_b,
                        track="server", args={"width": len(batch)})
        finally:
            # lifecycle invariants hold even when a batch dies terminally:
            # the injected-failure node is revived and the boundary scrub
            # ticks (background verify + repair of anything quarantined by
            # this flush's reads or the scrub itself)
            stats.wall_s = time.perf_counter() - t0
            if fail["node"] is not None:
                self.store.namenode.revive(fail["node"])
            if (self.config.recovery.scrub
                    and self.store.scrubber is not None):
                t_s = time.perf_counter()
                self.store.scrubber.tick()
                stats.scrub_s = time.perf_counter() - t_s
            # flush boundary: replication-controller quantum (this flush's
            # AccessLog heat moves replica counts — add hot / retire cold)
            if (self.store.layout == "pax"
                    and self.store.replicator is not None):
                self.store.replicator.tick()
        cluster = self.config.cluster
        overhead = stats.n_splits * cluster.hail_sched_overhead_s
        disk_s = stats.bytes_read / (cluster.disk_bw * cluster.n_nodes)
        stats.modeled_s = (overhead / (cluster.n_nodes * cluster.map_slots)
                           + disk_s)
        if self.cache:
            stats.cache_hits = self.cache.stats.hits - cache_h0
            stats.cache_misses = self.cache.stats.misses - cache_m0
        if rc:
            stats.result_cache_hits = rc.stats.hits - rc_h0
            stats.result_cache_misses = rc.stats.misses - rc_m0
        obs_trace.complete_wall("flush", t0, stats.wall_s, track="server",
                                args={"queries": stats.n_queries,
                                      "batches": stats.n_batches,
                                      "splits": stats.n_splits})
        obs_metrics.observe_flush(stats,
                                  tenants=[t.tenant for t in tickets])
        # one shared EXPLAIN context per flush: every ticket (result-cache
        # hits and failures included) can reconstruct its decomposition
        # lazily — the frontend enriches it with arrival/trigger/latency
        ctx = obs_explain.FlushExplain(stats, cluster)
        for t in tickets:
            t.explain_ctx = ctx
        return stats

    def _serve_from_result_cache(self, t: Ticket) -> bool:
        """Try to answer one ticket from the materialized-result tier.

        On a hit the ticket completes with ZERO reader dispatches; the
        entry's fill-time attribution recipe is replayed through
        ``governor.attribute_read`` so the AccessLog (and reader_stats)
        sees the same per-(replica, column) traffic the scan would have
        generated — a hot-but-result-cached index never looks LRU-cold."""
        rc = self.result_cache
        if (rc is None or self.store.layout != "pax"
                or t.query.filter is None):
            return False               # not result-cacheable: no miss counted
        col, lo, hi = t.query.filter
        ent = rc.lookup(col, lo, hi, tuple(t.query.projection),
                        self.store.version)
        if ent is None:
            return False
        for rid, n_idx, n_full in ent.attribution:
            gvn.attribute_read(self.store, rid, col, n_idx, n_full)
        t.result = QueryResult(n_rows=ent.n_rows, rows=dict(ent.rows),
                               batch_size=0, n_splits=0, from_cache=True)
        t.status = "done"
        return True

    def _read_batch(self, queries, qplan, ids):
        """-> (per-query ReadResults, physical shared bytes) for one split.

        PAX + filter + kernels reader is the shared-scan hot path; a
        row_ascii store routes to the Hadoop baseline reader (same as
        ``run_job``), and filterless/jnp reads fall back to per-query
        ``read_hail`` — no scan sharing, but one flush either way."""
        if self.store.layout != "pax":
            res = [q.read_hadoop(self.store, qq, ids) for qq in queries]
            return res, sum(r.bytes_read for r in res)
        if queries[0].filter is not None and self.config.reader == "kernels":
            return q.read_hail_batch(self.store, queries, qplan, ids)
        res = [q.read_hail(self.store, qq, qplan, ids) for qq in queries]
        return res, sum(r.bytes_read for r in res)

    def _live_members(self, qplan: q.QueryPlan, sp: Split,
                      queries: Sequence[HailQuery]) -> list[int]:
        """Batch-member indices whose ANSWER can depend on this split.

        A full-scan block touches every row, so it keeps the whole batch
        live (conservative: no key metadata to prune with).  An index-scan
        block's good rows span exactly [root-directory min, last good sorted
        key] — bad records sort to the tail — so a query range that misses
        that span on every block of the split contributes zero rows and the
        member need not wait on (or even dispatch) it."""
        store = self.store
        if store.layout != "pax" or queries[0].filter is None:
            return list(range(len(queries)))
        if any(not qplan.index_scan[b] for b in sp.block_ids):
            return list(range(len(queries)))
        col = queries[0].filter_col
        rows = store.rows_per_block
        bad = np.asarray(store.bad_counts)
        live: set[int] = set()
        for b in sp.block_ids:
            rep = store.replicas[int(qplan.replica_for_block[b])]
            n_good = rows - int(bad[b])
            if n_good <= 0:
                continue                     # every row bad: nothing to read
            kmin = int(np.asarray(rep.mins[b, 0]))
            kmax = int(np.asarray(rep.cols[col][b, n_good - 1]))
            for qi, qq in enumerate(queries):
                _, lo, hi = qq.filter
                if hi >= kmin and lo <= kmax:
                    live.add(qi)
            if len(live) == len(queries):
                break
        return sorted(live)

    def _empty_col(self, c: str) -> np.ndarray:
        """Zero-row column in the STORED dtype (a plan can yield zero live
        splits for a query; the empty answer must still type-check against
        the schema, not collapse to int32)."""
        if self.store.layout == "pax":
            return np.zeros((0,),
                            self.store.template_replica().cols[c].dtype)
        if c == ROWID:
            return np.zeros((0,), np.int32)
        return np.zeros((0,), self.store.schema.col(c).dtype)

    def _run_batch(self, batch: list[Ticket], stats: FlushStats,
                   budget: dict, fail: dict,
                   retries: collections.Counter, t0: float):
        """Execute one shared-scan batch: plan once, dispatch one fused call
        per split, piggyback shared-quantum adaptive builds, handle node
        failure AND read-path corruption by re-planning lost splits
        (per-block retries, bounded by ``config.recovery``) — the same loop
        shape as ``run_job``, widened to Q queries.  Completion STREAMS:
        each ticket finalizes the moment the last split it is live on
        clears the device barrier (``stats.query_done_s``), instead of at a
        batch-end barrier."""

        def note_retries(block_ids):
            for b in block_ids:
                retries[b] += 1
                if retries[b] > self.config.recovery.max_retries:
                    raise UnrecoverableDataError(
                        f"block {b}: re-plan retry budget "
                        f"({self.config.recovery.max_retries}) exhausted")

        store = self.store
        queries = [t.query for t in batch]
        query0 = queries[0]
        with obs_trace.span("plan", track="server",
                            args={"width": len(batch)}):
            qplan = q.plan(store, query0)
        splits = (hail_splits(store, qplan, self.config.cluster.map_slots)
                  if store.layout == "pax" else hadoop_splits(store, qplan))
        fail_after = (int(len(splits) * fail["frac"])
                      if fail["frac"] is not None and fail["node"] is None
                      else None)

        # claim-time adaptive state (shared flush budget as the quantum;
        # hysteresis + zero-quantum gating live in claim_adaptive_replica)
        adapt_col, adapt_rid = None, None
        demote_pending = 0.0
        if (self.config.adaptive is not None and store.layout == "pax"
                and query0.filter is not None and budget["left"] > 0):
            adapt_col = query0.filter_col
            adapt_rid, demoted, d_wall = mr.claim_adaptive_replica(
                store, adapt_col, budget["left"])
            stats.blocks_demoted += demoted
            demote_pending += d_wall
            if adapt_rid is not None and not len(
                    store.unindexed_blocks(adapt_rid)):
                adapt_rid = None             # already converged

        dispatched = []               # (results, shared_bytes, t, live qis)

        # sharded scan: buffer up to n_dev gathered splits per wave and
        # dispatch the wave as ONE shard_map'd fused call (mapreduce.run_job
        # has the serial-equivalence argument: gathered inputs are
        # snapshots, so buffering cannot change any split's row-set)
        use_sharded = (self.config.mesh is not None
                       and store.layout == "pax"
                       and query0.filter is not None)
        scan_axes: tuple = ()
        n_dev = 1
        if use_sharded:
            from repro.dist import sharding as shd
            scan_axes = shd.scan_mesh_axes(self.config.mesh)
            n_dev = shd.scan_device_count(self.config.mesh, scan_axes)
            use_sharded = bool(scan_axes) and n_dev > 1
        wave: list[tuple] = []        # (live qis, gathered inputs)

        def flush_wave():
            if not wave:
                return
            out = q.read_hail_batch_sharded(store, queries,
                                            [g for _, g in wave],
                                            self.config.mesh, scan_axes)
            for (live_qis, _), (res, shared) in zip(wave, out):
                dispatched.append((res, shared, time.perf_counter(),
                                   live_qis))
            wave.clear()

        pending = list(splits)
        i = 0
        try:
            while i < len(pending):
                if (fail_after is not None and i == fail_after
                        and fail["node"] is None):
                    pending, qplan, fail["node"], n_retries = \
                        mr.failover_replan(store, query0, pending, i)
                    stats.rescheduled_tasks += n_retries
                    if n_retries:
                        note_retries(b for s in pending[-n_retries:]
                                     for b in s.block_ids)
                    if i >= len(pending):
                        break
                sp = pending[i]
                i += 1
                live = self._live_members(qplan, sp, queries)
                if not live:
                    # DEAD split: no member's answer depends on it, and a
                    # dead split is all-index-scan so no piggyback build
                    # rides it — skip the dispatch entirely
                    continue
                try:
                    if use_sharded:
                        gathered = q.gather_shared_scan_inputs(
                            store, queries, qplan, list(sp.block_ids))
                        res = shared = None
                    else:
                        res, shared = self._read_batch(queries, qplan,
                                                       list(sp.block_ids))
                except CorruptBlockError as e:
                    # quarantine at the namenode, re-plan against the
                    # smaller replica set, re-queue this split's blocks as
                    # per-block retries — identical recovery to run_job's
                    store.quarantine_block(e.replica_id, e.block_id)
                    stats.blocks_quarantined += 1
                    stats.corrupt_retries += 1
                    note_retries(sp.block_ids)
                    qplan = q.plan(store, query0)
                    pending.extend(
                        Split(node=int(qplan.nodes[b]), block_ids=(b,),
                              index_scan=bool(qplan.index_scan[b]))
                        for b in sp.block_ids)
                    continue
                if use_sharded:
                    wave.append((tuple(live), gathered))
                else:
                    dispatched.append((res, shared, time.perf_counter(),
                                       tuple(live)))
                d_wall, demote_pending = demote_pending, 0.0
                b_wall = 0.0
                if adapt_rid is not None and budget["left"] > 0:
                    built, demoted, b_wall, dd_wall = mr.piggyback_build(
                        store, sp, adapt_rid, adapt_col, budget["left"])
                    budget["left"] -= built
                    stats.blocks_indexed += built
                    stats.blocks_demoted += demoted
                    d_wall += dd_wall
                stats.build_s.append(b_wall)
                stats.demote_s.append(d_wall)
                stats.batch_of_split.append(len(batch))
                stats.queries_of_split.append(
                    tuple(batch[qi].ticket_id for qi in live))
                n_idx = sum(bool(qplan.index_scan[b]) for b in sp.block_ids)
                stats.split_scan_modes.append(
                    (n_idx, len(sp.block_ids) - n_idx))
                if use_sharded and len(wave) == n_dev:
                    flush_wave()
            flush_wave()          # ragged final wave
        finally:
            if demote_pending > 0.0:
                # no split carried the demotion wall the claim paid (every
                # one was pruned or re-planned away, or the batch died
                # terminally): it must not vanish from the scheduler
                # bridge — charge the last executed split, else the flush
                # residue
                if stats.demote_s:
                    stats.demote_s[-1] += demote_pending
                else:
                    stats.demote_residue_s += demote_pending
                demote_pending = 0.0

        # completion: STREAMING — splits were all dispatched asynchronously
        # above, so blocking them in dispatch order finalizes each ticket
        # the moment the LAST split it is live on clears the barrier; a
        # ticket live on early-finishing (or zero) splits completes before
        # the slowest batch member
        n_splits = len(dispatched)
        stats.n_splits += n_splits
        rc = self.result_cache
        recipe = None
        if (rc is not None and store.layout == "pax"
                and query0.filter is not None):
            # the attribution recipe a HIT will replay — recomputed against
            # a FRESH plan, because mid-batch commits/quarantines bumped
            # ``store.version`` past the plan the reads actually used, and
            # the entry keyed at the CURRENT version must describe what a
            # scan at the current version would attribute
            try:
                recipe = q.attribution_groups(
                    q.plan(store, query0), np.arange(store.n_blocks))
            except UnrecoverableDataError:
                recipe = None          # can't describe a fresh scan: no fill

        per_query: list[list] = [[] for _ in queries]   # live ReadResults

        def finalize(qi: int):
            ticket, parts = batch[qi], per_query[qi]
            masks = [np.asarray(r.mask).reshape(-1) for r in parts]
            rows: dict[str, np.ndarray] = {}
            for c in tuple(ticket.query.projection) + (q.ROWID,):
                rows[c] = np.concatenate(
                    [np.asarray(r.cols[c]).reshape(-1)[m]
                     for r, m in zip(parts, masks)]) if parts else \
                    self._empty_col(c)
            n_rows = int(sum(m.sum() for m in masks))
            ticket.result = QueryResult(n_rows=n_rows, rows=rows,
                                        batch_size=len(batch),
                                        n_splits=n_splits)
            ticket.status = "done"
            stats.query_done_s[ticket.ticket_id] = time.perf_counter() - t0
            obs_trace.instant("finalize", track="server",
                              args={"ticket": ticket.ticket_id,
                                    "rows": n_rows})
            if recipe is not None:
                col, lo, hi = ticket.query.filter
                rc.put(col, lo, hi, tuple(ticket.query.projection),
                       store.version, rows, recipe)

        remaining = [0] * len(queries)     # live splits still outstanding
        for _, _, _, live in dispatched:
            for qi in live:
                remaining[qi] += 1
        for qi in range(len(queries)):
            if remaining[qi] == 0:
                finalize(qi)               # live on nothing: done at once
        for res, shared, t_disp, live in dispatched:
            jax.block_until_ready(res[0].mask)
            split_wall = time.perf_counter() - t_disp
            stats.split_s.append(split_wall)
            obs_trace.complete_wall("split", t_disp, split_wall,
                                    track="server",
                                    args={"batch_width": len(batch),
                                          "queries": [batch[qi].ticket_id
                                                      for qi in live]})
            stats.bytes_read += int(shared)
            for qi in live:
                per_query[qi].append(res[qi])
                remaining[qi] -= 1
                if remaining[qi] == 0:
                    finalize(qi)


# ---------------------------------------------------------------------------
# Async latency-SLO frontend (simulated-clock event loop over HailServer)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlushPolicy:
    """Auto-flush + fairness knobs for the ``ServerFrontend`` event loop.

    ``window_s`` is the latency-SLO knob: a flush cycle fires once the
    OLDEST pending query has waited this long (``float('inf')`` never
    auto-fires — the single-big-flush baseline, drained only by ``drain``).
    An infinite window disables the batch-full trigger too — the baseline
    is ONE big flush, not an accumulation that self-fires.
    ``max_batches_per_flush`` is one cycle's capacity; when more batches are
    pending, weighted-fair admission decides which dispatch first and the
    rest carry to the next cycle (None = no cap).  ``weights`` are per-
    tenant WFQ weights (default 1.0): under sustained overload a tenant
    with weight w receives ~w times the batch slots of a weight-1 tenant.
    """
    window_s: float = 0.05
    max_batches_per_flush: Optional[int] = None
    weights: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Arrival:
    """One offered query waiting in the frontend's admission queue."""
    seq: int
    query: HailQuery
    tenant: str
    arrival_s: float


class ServerFrontend:
    """Async serving loop with latency SLOs on top of a ``HailServer``.

    Callers ``offer`` queries stamped with SIMULATED arrival times; the
    event loop fires a flush cycle when the ``FlushPolicy`` says so — the
    oldest pending query is ``window_s`` old, or a compatible batch fills
    to ``max_batch`` — rather than a caller choosing when to ``flush``.
    Each cycle WFQ-admits up to ``max_batches_per_flush`` batches (per-
    tenant virtual time; leftovers carry), submits them through the
    server's admission control (over-quota members stay queued for the
    next cycle), flushes, and bridges the flush into the event-driven
    cluster simulator: per-query latency is

        max(trigger time, cluster busy-until) + that query's completion
        offset in the modeled schedule  -  its arrival time

    where the completion offset comes from ``run_schedule``'s
    ``query_completion_s`` (a query streams back when the LAST split it is
    live on finishes — result-cache hits and fully-pruned queries complete
    at offset 0).  The modeled cluster is busy until the schedule's
    makespan elapses, so back-to-back cycles queue behind each other —
    offered load beyond the service rate shows up as queueing latency,
    which is exactly the p50/p99-vs-load curve ``bench_server`` sweeps.
    """

    def __init__(self, server: HailServer,
                 policy: Optional[FlushPolicy] = None):
        self.server = server
        self.policy = policy or FlushPolicy()
        self.now = 0.0
        self.busy_until = 0.0          # sim time the modeled cluster frees
        self._queue: list[_Arrival] = []
        self._seq = 0
        self._vtime: dict[str, float] = collections.defaultdict(float)
        self.latencies: dict[int, float] = {}    # ticket id -> sim seconds
        self.completed: dict[int, Ticket] = {}
        self.failed: list[Ticket] = []
        self.flushes: list[FlushStats] = []

    # -- event loop ---------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def offer(self, query: HailQuery, tenant: str = "default",
              at: Optional[float] = None) -> None:
        """Enqueue one query arriving at simulated time ``at`` (default:
        now).  Window deadlines that elapse before the arrival fire first
        (in arrival-time order), then the batch-full trigger."""
        at = self.now if at is None else float(at)
        self._advance(at)
        self._queue.append(_Arrival(self._seq, query, tenant, self.now))
        self._seq += 1
        if (np.isfinite(self.policy.window_s)
                and self._full_batch_pending()):
            self._flush_cycle(self.now, trigger="batch_full")

    def drain(self) -> "ServerFrontend":
        """Flush until the queue empties (the end-of-workload drain; also
        the ONLY trigger under the ``window_s=inf`` baseline policy)."""
        while self._queue:
            if not self._flush_cycle(max(self.now, self.busy_until),
                                     trigger="drain"):
                break                  # nothing admissible: avoid spinning
        return self

    def percentile_latency(self, p: float) -> float:
        """NEAREST-RANK percentile of the completed queries' simulated
        latencies — pinned semantics (``obs.metrics.nearest_rank``, never
        interpolated), so bench p50/p99 guards always report an actually
        observed latency and small-N results cannot shift with a numpy
        interpolation default.

        >>> fe = ServerFrontend.__new__(ServerFrontend)
        >>> fe.latencies = {0: 1.0, 1: 2.0, 2: 3.0, 3: 4.0}
        >>> fe.percentile_latency(50)
        2.0
        >>> fe.percentile_latency(99)
        4.0
        >>> fe.percentile_latency(25)
        1.0
        """
        return obs_metrics.nearest_rank(self.latencies.values(), p)

    def _advance(self, to: float) -> None:
        """Fire every window deadline that falls at or before ``to``."""
        w = self.policy.window_s
        while self._queue:
            deadline = min(p.arrival_s for p in self._queue) + w
            if deadline > to:
                break
            if not self._flush_cycle(deadline, trigger="window"):
                break                  # nothing admissible: avoid spinning
        self.now = max(self.now, to)

    # -- flush cycle --------------------------------------------------------

    def _batch_key(self, p: _Arrival):
        # mirrors HailServer._batches: same (filter col, projection) means
        # one shared scan; filterless queries cannot share
        if p.query.filter is None or self.server.store.layout != "pax":
            return ("__single__", p.seq)
        return (p.query.filter_col, tuple(p.query.projection))

    def _full_batch_pending(self) -> bool:
        counts: collections.Counter = collections.Counter(
            self._batch_key(p) for p in self._queue)
        return any(n >= self.server.config.max_batch
                   for key, n in counts.items() if key[0] != "__single__")

    def _flush_cycle(self, trigger_s: float,
                     trigger: str = "manual") -> bool:
        """One cycle: WFQ-order the pending batches, admit up to the
        policy's capacity through the server, flush, and stream modeled
        per-query completion times into ``latencies``.  ``trigger`` names
        the policy condition that fired (window / batch_full / drain) —
        recorded on every admitted ticket's EXPLAIN context and trace
        events.  Returns whether any query was admitted (False = no
        progress possible right now)."""
        groups: dict = {}
        for p in self._queue:
            groups.setdefault(self._batch_key(p), []).append(p)
        maxb = self.server.config.max_batch
        batches = [members[i:i + maxb] for members in groups.values()
                   for i in range(0, len(members), maxb)]
        # WFQ: a batch's priority is its best member's tenant virtual time
        # (ties: earliest arrival) — dispatching advances each member
        # tenant's vtime by 1/weight, so heavy-weight tenants drain faster
        batches.sort(key=lambda b: (min(self._vtime[p.tenant] for p in b),
                                    min(p.arrival_s for p in b),
                                    min(p.seq for p in b)))
        cap = self.policy.max_batches_per_flush
        if cap is not None:
            batches = batches[:cap]
        admitted: list[tuple[_Arrival, Ticket]] = []
        taken: set[int] = set()
        for b in batches:
            for p in b:
                try:
                    tk = self.server.submit(p.query, tenant=p.tenant)
                except AdmissionError:
                    continue           # over quota: retained for next cycle
                admitted.append((p, tk))
                taken.add(p.seq)
                self._vtime[p.tenant] += (
                    1.0 / self.policy.weights.get(p.tenant, 1.0))
        if not admitted:
            return False
        self._queue = [p for p in self._queue if p.seq not in taken]
        start = max(trigger_s, self.busy_until)
        stats = self.server.flush()
        self.flushes.append(stats)
        cm = self.server.config.cluster
        tasks = flush_tasks(stats)
        sched = run_schedule(
            tasks,
            SimulatedCluster(n_nodes=cm.n_nodes, map_slots=cm.map_slots),
            spec_factor=None)
        # enrich the flush's shared EXPLAIN context with the frontend's
        # view: the firing trigger, simulated start, per-ticket arrivals —
        # and hand it THIS schedule, so explain() decomposes exactly the
        # latency reported below
        ctx = admitted[0][1].explain_ctx
        if ctx is not None:
            ctx.trigger = trigger
            ctx.start_s = start
            ctx.provide_schedule(sched, tasks)
        tracer = obs_trace.current()
        if tracer is not None:
            tracer.complete_sim(
                "flush_cycle", start, sched.makespan_s, track="frontend",
                args={"trigger": trigger, "queries": len(admitted),
                      "makespan_s": sched.makespan_s})
            # query slices (and their flow STARTS) go first, so the
            # schedule's per-task flow steps chain arrival -> splits
            for p, tk in admitted:
                done = start + sched.query_completion_s.get(
                    tk.ticket_id, 0.0)
                tracer.complete_sim(
                    f"q{tk.ticket_id}", p.arrival_s, done - p.arrival_s,
                    track=f"tenant {tk.tenant}",
                    args={"ticket": tk.ticket_id, "trigger": trigger,
                          "queue_wait_s": start - p.arrival_s})
                tracer.flow("s", tk.ticket_id, p.arrival_s,
                            track=f"tenant {tk.tenant}")
            tracer.add_schedule(sched, tasks, base_s=start)
        for p, tk in admitted:
            self.completed[tk.ticket_id] = tk
            if ctx is not None:
                ctx.arrival_s[tk.ticket_id] = p.arrival_s
            if tk.status == "failed":
                self.failed.append(tk)
                continue
            done = start + sched.query_completion_s.get(tk.ticket_id, 0.0)
            self.latencies[tk.ticket_id] = done - p.arrival_s
            if ctx is not None:
                ctx.latency_s[tk.ticket_id] = done - p.arrival_s
        self.busy_until = start + sched.makespan_s
        self.now = max(self.now, trigger_s)
        return True
