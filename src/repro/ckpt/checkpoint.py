"""Checkpointing: per-leaf files, CRC checksums, atomic publish, resume.

Layout:  <dir>/step_<N>/
            manifest.json   {path: {shape, dtype, crc32, bytes}}
            <flat.key>.npy  one file per pytree leaf

Guarantees (tested in tests/test_checkpoint.py):
  * atomic publish — a crashed save never shadows the latest good step
    (write to step_N.tmp, fsync, rename);
  * corruption detection — CRC per leaf at restore; a corrupt step is
    skipped and the previous valid step is restored instead;
  * elastic restore — leaves are stored as full (unsharded) arrays, so a
    checkpoint written on one mesh restores onto any other mesh/data-parallel
    degree via reshard() (elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

SEP = "::"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _unflatten_like(template: Any, flat: dict[str, Any]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, _ in paths:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(state: Any, ckpt_dir: str, step: int) -> str:
    """Synchronous atomic save. Returns the published directory."""
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(state).items()}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {}
    for key, arr in flat.items():
        fn = key.replace("/", "_") + ".npy"
        p = os.path.join(tmp, fn)
        np.save(p, arr)
        with open(p, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest[fn] = {"key": key, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "crc32": crc,
                        "bytes": int(arr.nbytes)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncSaver:
    """Overlap checkpoint writes with the next train steps (device_get on
    the caller, file I/O on a worker thread)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, state: Any, ckpt_dir: str, step: int):
        host_state = jax.tree.map(lambda v: np.asarray(jax.device_get(v)), state)
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(host_state, ckpt_dir, step), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _verify(step_dir: str) -> Optional[dict]:
    mpath = os.path.join(step_dir, "manifest.json")
    if not os.path.exists(mpath):
        return None
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for fn, info in manifest["leaves"].items():
            p = os.path.join(step_dir, fn)
            with open(p, "rb") as f:
                if zlib.crc32(f.read()) != info["crc32"]:
                    return None
        return manifest
    except Exception:
        return None


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(out)


def restore_latest(ckpt_dir: str, template: Any, *, specs: Any = None,
                   mesh=None, rules=None) -> tuple[Optional[Any], Optional[int]]:
    """Restore the newest step whose checksums verify; skip corrupt ones.
    With (specs, mesh), leaves are placed with elastic resharding."""
    for step in reversed(list_steps(ckpt_dir)):
        step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
        manifest = _verify(step_dir)
        if manifest is None:
            continue
        flat = {}
        for fn, info in manifest["leaves"].items():
            flat[info["key"]] = np.load(os.path.join(step_dir, fn))
        state = _unflatten_like(template, flat)
        if mesh is not None and specs is not None:
            state = reshard(state, specs, mesh, rules)
        return state, step
    return None, None


def reshard(state: Any, specs: Any, mesh, rules=None) -> Any:
    """device_put every leaf with the sharding its TensorSpec resolves to on
    the (possibly different) mesh — elastic scale-up/down of 'data'.
    ``specs`` is a TensorSpec tree matching ``state``'s structure."""
    from repro.dist import sharding as sh

    flat_state = _flatten(state)
    flat_specs = _flatten(specs)
    out = {}
    for k, v in flat_state.items():
        spec = flat_specs.get(k)
        if spec is not None and sh.is_spec(spec):
            out[k] = jax.device_put(v, sh.named_sharding(spec, mesh, rules))
        else:
            out[k] = jax.device_put(v)
    return _unflatten_like(state, out)
