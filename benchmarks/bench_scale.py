"""Paper Table 2 (scale-up) + Fig 5 (scale-out).

Scale-up: EC2 node classes differ in CPU speed and disks; we model node
classes as (compute_factor, disk_bw, disks) and combine with the measured
parse/sort/index compute — HAIL gains more from better CPUs because its
upload is compute-heavy while Hadoop's is I/O-bound (the paper's point).

Scale-out: constant data per node; per-node work is constant, so modeled
upload time stays flat while aggregate throughput scales linearly.
"""
from __future__ import annotations

from benchmarks.common import NODES, synthetic_raw, uservisits_raw
from repro.core import schema as sc
from repro.core import upload as up

# (name, cpu_factor, disk_bw per node)
NODE_CLASSES = [("large", 0.5, 60e6), ("xlarge", 0.8, 80e6),
                ("quadruple", 1.0, 100e6), ("physical", 1.2, 120e6)]


def _stats(schema, raw, keys):
    up.hail_upload(schema, raw[:2], keys, n_nodes=NODES)
    _, s = up.hail_upload(schema, raw, keys, n_nodes=NODES)
    return s


def run():
    rows = []
    for tag, (_, raw), schema, keys in (
            ("uservisits", uservisits_raw(), sc.USERVISITS,
             ["visitDate", "sourceIP", "adRevenue"]),
            ("synthetic", synthetic_raw(), sc.SYNTHETIC,
             ["attr0", "attr1", "attr2"])):
        hail = _stats(schema, raw, keys)
        _, hadoop = up.hdfs_upload(schema, raw, n_nodes=NODES)
        from benchmarks.common import upload_model_seconds
        for name, cpu, disk in NODE_CLASSES:
            h_t = upload_model_seconds(hadoop, disk_bw=disk, cpu_factor=cpu)
            a_t = upload_model_seconds(hail, disk_bw=disk, cpu_factor=cpu)
            rows.append((f"table2_{tag}_{name}", a_t * 1e6,
                         f"system_speedup={h_t / a_t:.2f}"))
    # Fig 5: scale-out, constant per-node data
    _, raw = synthetic_raw()
    hail = _stats(sc.SYNTHETIC, raw, ["attr0", "attr1", "attr2"])
    per_node_bytes = hail.written_bytes / NODES
    per_node_compute = hail.wall_s / (NODES * 4)
    for n in (10, 50, 100):
        t = max(per_node_compute, per_node_bytes / 100e6)
        thru = n * per_node_bytes / t / 1e6
        rows.append((f"fig5_scaleout_{n}nodes", t * 1e6,
                     f"aggregate_MBps={thru:.0f}"))
    return rows
