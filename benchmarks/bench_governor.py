"""Index-governor reconvergence under a workload shift (two-phase bench).

A lazy store is governed by a one-replica storage budget
(``max_indexed_blocks = n_blocks``).  Phase A converges the adaptive path
on ``visitDate``; then the workload SHIFTS to ``sourceIP``: the budget is
full, so the first phase-B job evicts phase A's replica (LRU victim),
re-claims it, and the store reconverges on the new column — in
``ceil(1/offer_rate)`` jobs, the same model as first-time convergence
(EXPERIMENTS.md).  Reported per job and phase: deterministic modeled
latency, indexed fractions for both columns, blocks demoted/built, and the
total indexed blocks (the budget guard).  The CI regression guard fails if
the budget is ever exceeded, if either phase's modeled curve increases, or
if the reconverged job is >10% slower than the eager-index baseline.
"""
from __future__ import annotations

import argparse
import json
import math
import os

from benchmarks.common import obs_snapshot, obs_sum, uservisits_raw
from repro.core import governor as gv
from repro.obs import metrics as obs_metrics
from repro.core import mapreduce as mr
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.query import HailQuery

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_kernels.json")

OFFER_RATE = 0.5
QUERY_A = HailQuery(filter=("visitDate", 7305, 9000), projection=("sourceIP",))
QUERY_B = HailQuery(filter=("sourceIP", 0, 1 << 30), projection=("visitDate",))


def _phase(store, query, cfg, cluster, n_jobs, col, base_rows):
    out = {"modeled_s": [], "frac": [], "built": [], "demoted": [],
           "total_indexed": [], "rekey_s": 0.0}
    for _ in range(n_jobs):
        st = mr.run_job(store, query, adaptive=cfg, cluster=cluster)
        assert st.results["n_rows"] == base_rows
        out["modeled_s"].append(round(st.modeled_s, 4))
        out["frac"].append(round(store.indexed_fraction(col), 4))
        out["built"].append(st.blocks_indexed)
        out["demoted"].append(st.blocks_demoted)
        out["total_indexed"].append(store.total_indexed_blocks())
        out["rekey_s"] += st.rekey_s
    out["rekey_s"] = round(out["rekey_s"], 4)
    return out


def workload_shift(blocks: int = 24, rows: int = 2048,
                   offer_rate: float = OFFER_RATE) -> dict:
    cluster = mr.ClusterModel(n_nodes=6, map_slots=1)
    _, raw = uservisits_raw(blocks=blocks, rows=rows)
    eager, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              n_nodes=cluster.n_nodes)
    lazy, _ = up.hail_upload(sc.USERVISITS, raw, index_columns=(),
                             replication=3, n_nodes=cluster.n_nodes)
    budget = blocks                       # exactly one replica's worth
    gov = gv.govern(lazy, max_indexed_blocks=budget)

    base_a = mr.run_job(eager, QUERY_A, cluster=cluster)   # warm reader jit
    base_a = mr.run_job(eager, QUERY_A, cluster=cluster)
    base_b = mr.run_job(eager, QUERY_B, cluster=cluster)

    n_jobs = math.ceil(1 / offer_rate) + 2
    cfg = mr.AdaptiveConfig(offer_rate=offer_rate)
    phase_a = _phase(lazy, QUERY_A, cfg, cluster, n_jobs, "visitDate",
                     base_a.results["n_rows"])
    phase_b = _phase(lazy, QUERY_B, cfg, cluster, n_jobs, "sourceIP",
                     base_b.results["n_rows"])

    monotone = all(
        all(a >= b - 1e-9 for a, b in zip(ph["modeled_s"],
                                          ph["modeled_s"][1:]))
        for ph in (phase_a, phase_b))
    reconverge_jobs = next(i + 1 for i, f in enumerate(phase_b["frac"])
                           if f >= 1.0)
    return {
        "governor_offer_rate": offer_rate,
        "governor_budget_blocks": budget,
        "governor_phase_a_modeled_s": phase_a["modeled_s"],
        "governor_phase_b_modeled_s": phase_b["modeled_s"],
        "governor_phase_a_frac": phase_a["frac"],
        "governor_phase_b_frac": phase_b["frac"],
        "governor_blocks_demoted": phase_a["demoted"] + phase_b["demoted"],
        "governor_blocks_built": phase_a["built"] + phase_b["built"],
        "governor_total_indexed": (phase_a["total_indexed"]
                                   + phase_b["total_indexed"]),
        "governor_budget_ok": max(phase_a["total_indexed"]
                                  + phase_b["total_indexed"]) <= budget,
        "governor_phase_monotone": monotone,
        "governor_rekey_wall_s": round(phase_a["rekey_s"]
                                       + phase_b["rekey_s"], 4),
        "governor_demotions_total": gov.blocks_demoted_total,
        "governor_jobs_to_reconverge": reconverge_jobs,
        "governor_jobs_to_reconverge_model": math.ceil(1 / offer_rate),
        "governor_eager_modeled_s": round(base_b.modeled_s, 4),
        "governor_reconverged_vs_eager": round(
            phase_b["modeled_s"][-1] / base_b.modeled_s, 4),
    }


def run(quick: bool = False):
    blocks, rows = (12, 1024) if quick else (24, 2048)
    reg0 = obs_snapshot()
    d = workload_shift(blocks=blocks, rows=rows)
    # registry view of the same shift: per-(replica, column) demotion
    # counters must total the governor's own event log
    reg = obs_metrics.delta(reg0)
    d["obs_governor_demoted_blocks"] = int(
        obs_sum(reg, "governor.demoted_blocks"))
    d["obs_governor_demotion_events"] = int(
        obs_sum(reg, "governor.demotion_events"))
    d["obs_governor_counters_agree"] = (
        d["obs_governor_demoted_blocks"] == d["governor_demotions_total"])

    blob = {}
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as f:
            blob = json.load(f)
    blob.update(d)
    with open(JSON_PATH, "w") as f:
        json.dump(blob, f, indent=1)

    rows_out = [
        ("governor_shift_job", d["governor_phase_b_modeled_s"][0] * 1e6,
         f"demoted={d['governor_blocks_demoted'][len(d['governor_phase_a_frac'])]};"
         f"rekey_wall_s={d['governor_rekey_wall_s']}"),
        ("governor_reconverged_job", d["governor_phase_b_modeled_s"][-1] * 1e6,
         f"eager_us={d['governor_eager_modeled_s'] * 1e6:.0f};"
         f"ratio={d['governor_reconverged_vs_eager']:.3f};"
         f"jobs={d['governor_jobs_to_reconverge']}"
         f"/model={d['governor_jobs_to_reconverge_model']}"),
    ]
    for k, (m, f) in enumerate(zip(d["governor_phase_b_modeled_s"],
                                   d["governor_phase_b_frac"])):
        rows_out.append((f"governor_phase_b_job_{k}", m * 1e6,
                         f"frac_b={f};"
                         f"total_indexed={d['governor_total_indexed'][len(d['governor_phase_a_frac']) + k]}"))
    return rows_out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small store for CI (12x1024 blocks)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=args.quick):
        print(f"{name},{us:.1f},{derived}")
