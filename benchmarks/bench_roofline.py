"""Roofline table from the dry-run artifacts (framework deliverable g).
Reads artifacts/dryrun/*.json; derived column = dominant term + roofline
fraction (MODEL_FLOPS-based MFU upper bound at the step's bound)."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh: str = "single", tag: str = ""):
    cells = {}
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(f))
        if r.get("mesh") != mesh or r.get("tag", "") != tag or "error" in r:
            continue
        cells[(r["arch"], r["shape"])] = r
    return cells


def run():
    rows = []
    base = load_cells("single", "")
    opt = load_cells("single", "vopt")
    for label, cells in (("base", base), ("opt", opt)):
        for (arch, shape), r in sorted(cells.items()):
            t = r["roofline"]
            frac = r.get("roofline_fraction")
            rows.append((
                f"roofline[{label}]_{arch}_{shape}",
                t["step_lower_bound_s"] * 1e6,
                f"dominant={t['dominant']};compute_s={t['compute_s']:.3g};"
                f"memory_s={t['memory_s']:.3g};coll_s={t['collective_s']:.3g};"
                f"mfu_bound={frac if frac is None else round(frac, 4)};"
                f"model/hlo={round(r.get('model_over_hlo_flops') or 0, 3)}"))
    # §Perf summary: baseline vs optimized step-bound speedup
    import numpy as np
    logs = [np.log(base[k]["roofline"]["step_lower_bound_s"]
                   / opt[k]["roofline"]["step_lower_bound_s"])
            for k in base if k in opt]
    if logs:
        rows.append(("perf_geomean_bound_speedup", 0.0,
                     f"opt_vs_baseline={np.exp(np.mean(logs)):.2f}x_over_"
                     f"{len(logs)}_cells"))
    # multi-pod pass/fail summary
    for label, tag in (("base", ""), ("opt", "vopt")):
        multi = load_cells("multi", tag)
        rows.append((f"dryrun_multi_pod_cells[{label}]", 0.0,
                     f"compiled_ok={len(multi)}"))
    return rows
