"""Corruption-resilience bench: clean-path verify tax + detect/repair cost.

Three regression-guarded questions (EXPERIMENTS.md, "Corruption
resilience"):

* CLEAN-PATH TAX — read-path checksum verification is amortized to
  BlockCache fills, so a warm-cache ``HailServer.flush`` re-verifies
  nothing: its scheduler-bridged makespan with ``verify_reads=True`` must
  stay within 10% of ``verify_reads=False`` (the ISSUE's acceptance
  bound), and the warm flush must issue ZERO ``verify_blocks`` dispatches;
* CORRECTNESS UNDER CORRUPTION — a bit-flipped replica block must not
  change any query's row count (detect -> quarantine -> re-plan to a
  healthy replica), and all-replicas corruption must surface
  ``UnrecoverableDataError``, never silent wrong rows;
* REPAIR COST + FIDELITY — ``repair_blocks`` rebuilds the victim from a
  healthy replica under the victim's own sort order; the modeled cost is
  the detection job's latency plus the rewritten bytes over the paper's
  100MB/s disk, and the repaired replica's root directory must equal a
  fresh eager upload's (the clustered index survives repair).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import obs_snapshot, obs_sum, uservisits_raw
from repro.core import mapreduce as mr
from repro.obs import metrics as obs_metrics
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.fault import FaultInjector, UnrecoverableDataError
from repro.core.query import HailQuery
from repro.kernels import ops
from repro.runtime import jobserver as js
from repro.runtime.cluster import SimulatedCluster
from repro.runtime.scheduler import Task, run_schedule

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_kernels.json")

KEYS = ["visitDate", "sourceIP", "adRevenue"]
RANGES = [(7305, 7670), (8000, 9500), (7000, 12000), (9000, 9001)]


def _warm_flush_makespan(store, queries, cluster):
    """Scheduler-bridged makespan of a WARM-cache flush (same per-task
    scheduling constant as bench_server, so ratios isolate the verify
    tax), plus the verify dispatches that flush issued."""
    # result_cache off: this measures the warm SCAN path (block-cache hits
    # + zero verify dispatches), which the result tier would short-circuit
    server = js.HailServer(store, js.ServerConfig(max_batch=len(queries),
                                                  cluster=cluster,
                                                  result_cache=False))
    for qq in queries:
        server.submit(qq)
    server.flush()                          # cold: compiles + fills cache
    for qq in queries:
        server.submit(qq)
    with ops.stats_scope() as s:
        fl = server.flush()                 # warm: all hits
    tasks = [Task(i, cluster.hail_sched_overhead_s + d, preferred_nodes=(),
                  index_build_s=b, rekey_s=r, n_queries=nq)
             for i, (d, b, r, nq) in enumerate(zip(
                 fl.split_s, fl.build_s, fl.demote_s, fl.batch_of_split))]
    sched = run_schedule(tasks, SimulatedCluster(cluster.n_nodes,
                                                 cluster.map_slots),
                         spec_factor=None)
    rows = [t.result.n_rows for t in server.tickets[-len(queries):]]
    return sched.makespan_s, s.dispatches["verify_blocks"], rows


def corruption_resilience(blocks: int = 24, rows: int = 2048) -> dict:
    cluster = mr.ClusterModel(n_nodes=6, map_slots=2)
    _, raw = uservisits_raw(blocks=blocks, rows=rows)
    mk = lambda: up.hail_upload(sc.USERVISITS, raw, KEYS,  # noqa: E731
                                n_nodes=cluster.n_nodes)[0]
    queries = [HailQuery(filter=("visitDate", lo, hi),
                         projection=("sourceIP",)) for lo, hi in RANGES]

    # --- clean path: verify-on warm flush vs verify-off -------------------
    son, soff = mk(), mk()
    soff.verify_reads = False
    on_makespan, on_verifies, on_rows = _warm_flush_makespan(
        son, queries, cluster)
    off_makespan, _, off_rows = _warm_flush_makespan(soff, queries, cluster)
    assert on_rows == off_rows
    overhead = on_makespan / off_makespan

    # --- corruption: detect -> quarantine -> re-plan -> same rows ---------
    clean = mr.run_job(son, queries[0], cluster=cluster)
    victim_block = blocks // 2
    FaultInjector(son, seed=3).corrupt_chunk(0, victim_block, "visitDate")
    son.block_cache.clear()                # cold fills -> read-path detect
    detect = mr.run_job(son, queries[0], cluster=cluster)
    rows_ok = (detect.results["n_rows"] == clean.results["n_rows"]
               and detect.blocks_quarantined == 1)

    # --- repair: cost model + index fidelity ------------------------------
    rs = son.repair_blocks()
    repair_modeled = detect.modeled_s + rs.bytes_rewritten / cluster.disk_bw
    fresh = mk()
    index_ok = (rs.blocks_repaired == 1 and son.verify_block(0, victim_block)
                and np.array_equal(np.asarray(son.replicas[0].mins),
                                   np.asarray(fresh.replicas[0].mins))
                and np.array_equal(
                    np.asarray(son.replicas[0].cols["visitDate"]),
                    np.asarray(fresh.replicas[0].cols["visitDate"])))

    # --- all replicas corrupt: typed failure, never wrong rows ------------
    sdead = mk()
    FaultInjector(sdead, seed=4).corrupt_replicas(
        victim_block, sdead.replication, "visitDate")
    try:
        mr.run_job(sdead, queries[0], cluster=cluster)
        unrecoverable_detected = False
    except UnrecoverableDataError:
        unrecoverable_detected = True

    return {
        "fault_blocks": blocks,
        "fault_verify_overhead_ratio": round(overhead, 4),
        "fault_warm_verify_dispatches": int(on_verifies),
        "fault_verify_on_makespan_s": round(on_makespan, 4),
        "fault_verify_off_makespan_s": round(off_makespan, 4),
        "fault_rows_under_corruption_ok": bool(rows_ok),
        "fault_blocks_quarantined": int(detect.blocks_quarantined),
        "fault_corrupt_retries": int(detect.corrupt_retries),
        "fault_blocks_repaired": int(rs.blocks_repaired),
        "fault_bytes_rewritten": int(rs.bytes_rewritten),
        "fault_detect_repair_modeled_s": round(repair_modeled, 4),
        "fault_repair_index_preserved": bool(index_ok),
        "fault_unrecoverable_detected": bool(unrecoverable_detected),
    }


def run(quick: bool = False):
    blocks, rows = (12, 1024) if quick else (24, 2048)
    reg0 = obs_snapshot()
    d = corruption_resilience(blocks=blocks, rows=rows)
    # registry view of the same section: the quarantine counter crosses
    # flush AND job paths, so it must cover at least the detection job's
    reg = obs_metrics.delta(reg0)
    d["obs_fault_blocks_quarantined"] = int(
        obs_sum(reg, "job.blocks_quarantined")
        + obs_sum(reg, "flush.blocks_quarantined"))
    d["obs_fault_corrupt_retries"] = int(
        obs_sum(reg, "job.corrupt_retries")
        + obs_sum(reg, "flush.corrupt_retries"))
    d["obs_fault_counters_agree"] = (
        d["obs_fault_blocks_quarantined"] >= d["fault_blocks_quarantined"])

    blob = {}
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as f:
            blob = json.load(f)
    blob.update(d)
    with open(JSON_PATH, "w") as f:
        json.dump(blob, f, indent=1)

    return [
        ("fault_verify_tax", d["fault_verify_overhead_ratio"],
         f"warm_verify_dispatches={d['fault_warm_verify_dispatches']};"
         f"on={d['fault_verify_on_makespan_s']}s"
         f"/off={d['fault_verify_off_makespan_s']}s"),
        ("fault_detect_repair", d["fault_detect_repair_modeled_s"] * 1e6,
         f"quarantined={d['fault_blocks_quarantined']};"
         f"repaired={d['fault_blocks_repaired']};"
         f"bytes={d['fault_bytes_rewritten']};"
         f"rows_ok={d['fault_rows_under_corruption_ok']};"
         f"index_preserved={d['fault_repair_index_preserved']}"),
        ("fault_unrecoverable", float(d["fault_unrecoverable_detected"]),
         "all-R corruption raises UnrecoverableDataError"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small store for CI (12x1024 blocks)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=args.quick):
        print(f"{name},{us:.1f},{derived}")
