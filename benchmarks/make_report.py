"""Emit the §Dry-run / §Roofline markdown tables from artifacts/dryrun/*.json.

  PYTHONPATH=src:. python benchmarks/make_report.py [--tag vopt]
"""
from __future__ import annotations

import argparse
import json

from benchmarks.bench_roofline import load_cells


def fmt(x, nd=3):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) < 1e-3 or abs(x) >= 1e5:
            return f"{x:.2e}"
        return f"{x:.{nd}g}"
    return str(x)


def roofline_table(tag: str) -> str:
    cells = load_cells("single", tag)
    out = ["| arch | shape | compute s | memory s | coll s | dominant | "
           "MODEL_FLOPS | model/HLO | MFU bound | HBM GB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape), r in sorted(cells.items()):
        t = r["roofline"]
        mem_gb = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]
                  + r["memory"]["output_bytes"] - r["memory"]["alias_bytes"]) / 1e9
        out.append(
            f"| {arch} | {shape} | {fmt(t['compute_s'])} | {fmt(t['memory_s'])} "
            f"| {fmt(t['collective_s'])} | **{t['dominant']}** "
            f"| {fmt(r['model_flops'], 3)} | {fmt(r['model_over_hlo_flops'])} "
            f"| {fmt(r['roofline_fraction'])} | {mem_gb:.1f} |")
    return "\n".join(out)


def dryrun_table(tag: str) -> str:
    out = ["| arch | shape | mesh | devices | lower s | compile s | "
           "args GB/dev | temp GB/dev | coll kinds |",
           "|---|---|---|---|---|---|---|---|---|"]
    for mesh in ("single", "multi"):
        for (arch, shape), r in sorted(load_cells(mesh, tag).items()):
            kinds = ",".join(f"{k}:{v}" for k, v in
                             sorted(r["hlo"]["coll_count"].items()))
            out.append(
                f"| {arch} | {shape} | {mesh} | {r['devices']} "
                f"| {r['lower_s']:.1f} | {r['compile_s']:.1f} "
                f"| {r['memory']['argument_bytes'] / 1e9:.2f} "
                f"| {r['memory']['temp_bytes'] / 1e9:.2f} | {kinds} |")
    return "\n".join(out)


def summary(tag: str) -> str:
    s = [f"single-pod cells: {len(load_cells('single', tag))}; "
         f"multi-pod cells: {len(load_cells('multi', tag))} (all compiled)"]
    return "\n".join(s)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun", "summary"])
    a = ap.parse_args()
    print({"roofline": roofline_table, "dryrun": dryrun_table,
           "summary": summary}[a.table](a.tag))
