"""Multi-device sharded flush scans + heat-driven dynamic replication.

Two sections, both on a FORCED 8-device host platform (set before any jax
import, like launch/dryrun_hail.py):

* **Sharded scans** — one job's splits dispatch in waves of n_dev through
  the shard_map'd fused reader: per-device fused dispatches drop from S
  (serial) to ceil(S / n_dev), the paper's fewer-dispatches-per-worker win
  widened across devices.  The guard pins the dispatch model exactly and
  requires row-set equality with the single-device oracle.

* **Dynamic replication** — the ReplicationController replaces the static
  factor-of-3: a hot filter column with no index slot triggers
  ``add_replica`` (the next adaptive job claims + converges it); after the
  workload shifts away the replica's heat delta flatlines and it is
  decommissioned back down.  The guard requires at least one full
  add -> claim -> decommission cycle and the post-claim job to be fully
  index-scanned.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402

from benchmarks.common import timed, uservisits_raw  # noqa: E402
from repro.core import governor as gv  # noqa: E402
from repro.core import mapreduce as mr  # noqa: E402
from repro.core import schema as sc  # noqa: E402
from repro.core import upload as up  # noqa: E402
from repro.core.query import HailQuery  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_kernels.json")

N_DEV = 8
QUERY = HailQuery(filter=("visitDate", 7305, 9000), projection=("sourceIP",))
Q_HOT = HailQuery(filter=("adRevenue", 100, 20000), projection=("sourceIP",))
Q_VD = HailQuery(filter=("visitDate", 7305, 7670), projection=("sourceIP",))
Q_SIP = HailQuery(filter=("sourceIP", 0, 1 << 30), projection=("visitDate",))


def sharded_scan(blocks: int, rows: int) -> tuple[dict, list]:
    import jax
    mesh = make_mesh((jax.device_count(),), ("data",))
    cluster = mr.ClusterModel(n_nodes=6, map_slots=1)
    _, raw = uservisits_raw(blocks=blocks, rows=rows)
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP"],
                              n_nodes=cluster.n_nodes)

    mr.run_job(store, QUERY, cluster=cluster, mesh=mesh)      # warm jit
    with ops.stats_scope() as st:
        wall_sh, job_sh = timed(mr.run_job, store, QUERY, cluster=cluster,
                                mesh=mesh, warmup=0, reps=3)
    wall_se, job_se = timed(mr.run_job, store, QUERY, cluster=cluster,
                            warmup=1, reps=3)
    s = len(job_sh.split_s)
    waves = st.dispatches["hail_read_sharded_waves"] // 3    # 3 timed reps
    model = math.ceil(s / N_DEV)
    d = {
        "dist_n_devices": N_DEV,
        "dist_splits": s,
        "dist_waves": waves,
        "dist_per_device_dispatches": waves,
        "dist_dispatch_model": model,
        "dist_makespan_ratio": round(model / s, 4),
        # ^ modeled per-device fused-dispatch ratio, sharded vs serial (the
        #   serial path issues all S dispatches on one device)
        "dist_rows_equal": (job_sh.results["n_rows"]
                            == job_se.results["n_rows"]
                            and job_sh.bytes_read == job_se.bytes_read),
        "dist_sharded_wall_s": round(wall_sh, 4),
        "dist_serial_wall_s": round(wall_se, 4),
    }
    rows_out = [
        ("dist_sharded_job", wall_sh * 1e6,
         f"splits={s};waves={waves};model={model};"
         f"rows_equal={d['dist_rows_equal']}"),
        ("dist_serial_job", wall_se * 1e6,
         f"per_dev_ratio={d['dist_makespan_ratio']}"),
    ]
    return d, rows_out


def replication_cycle(blocks: int, rows: int) -> tuple[dict, list]:
    from repro.obs.metrics import MetricsRegistry
    cluster = mr.ClusterModel(n_nodes=6, map_slots=1)
    _, raw = uservisits_raw(blocks=blocks, rows=rows)
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP"],
                              n_nodes=cluster.n_nodes)
    ctl = gv.replicate(store, min_replication=2, max_replication=5,
                       hot_misses=1, cold_ticks=4,
                       registry=MetricsRegistry())
    adaptive = mr.AdaptiveConfig(offer_rate=1.0)
    run = lambda qq: mr.run_job(store, qq, adaptive=adaptive,  # noqa: E731
                                cluster=cluster)

    # hot phase: adRevenue has no index slot -> miss heat adds a replica at
    # the first boundary; the next adRevenue job claims + converges it
    # (visitDate / sourceIP interleave so the original replicas stay warm)
    hot_modeled = [run(Q_HOT).modeled_s]
    live_after_add = len(store.live_replica_ids())
    for qq in (Q_VD, Q_SIP, Q_HOT):
        run(qq)
    converged = run(Q_HOT)
    # shifted phase: adRevenue vanishes -> the added replica's heat delta
    # flatlines for cold_ticks boundaries and it is decommissioned
    for _ in range(4):
        run(Q_VD)
        run(Q_SIP)
    d = {
        "dist_replicas_added": ctl.replicas_added,
        "dist_replicas_decommissioned": ctl.replicas_decommissioned,
        "dist_live_replicas_peak": live_after_add,
        "dist_live_replicas_final": len(store.live_replica_ids()),
        "dist_hot_modeled_s": round(hot_modeled[0], 4),
        "dist_converged_modeled_s": round(converged.modeled_s, 4),
        "dist_converged_full_scan_blocks": converged.full_scan_blocks,
        "dist_replication_ticks": ctl.ticks,
    }
    rows_out = [
        ("dist_replication_hot_job", hot_modeled[0] * 1e6,
         f"added={ctl.replicas_added};peak_live={live_after_add}"),
        ("dist_replication_converged_job", converged.modeled_s * 1e6,
         f"full_scan_blocks={converged.full_scan_blocks};"
         f"decommissioned={ctl.replicas_decommissioned};"
         f"final_live={d['dist_live_replicas_final']}"),
    ]
    return d, rows_out


def run(quick: bool = False):
    blocks, rows = (12, 512) if quick else (32, 2048)
    d, rows_out = sharded_scan(blocks, rows)
    d2, rows2 = replication_cycle(blocks, rows)
    d.update(d2)
    rows_out += rows2

    blob = {}
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as f:
            blob = json.load(f)
    blob.update(d)
    with open(JSON_PATH, "w") as f:
        json.dump(blob, f, indent=1)
    return rows_out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small store for CI (12x512 blocks)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=args.quick):
        print(f"{name},{us:.1f},{derived}")
