"""Adaptive indexing (LIAH) convergence: job-k latency vs k, and the
lazy-upload vs eager-HAIL tradeoff.

A store uploaded with ``index_columns=()`` starts all-full-scan; repeated
``run_job(adaptive=AdaptiveConfig(offer_rate))`` calls piggyback index
builds on full-scan splits until every block is index-scanned.  Reported
per job k: the DETERMINISTIC modeled latency (scheduling + disk — immune
to container noise), measured end-to-end, bytes read, and blocks indexed.
The converged job is compared against the same job on an eagerly indexed
store — the regression guard in BENCH_kernels.json fails CI if the
converged job is >10% slower than the eager baseline, or if the modeled
convergence curve ever increases.
"""
from __future__ import annotations

import argparse
import json
import math
import os

from benchmarks.common import obs_snapshot, obs_sum, timed, uservisits_raw
from repro.obs import metrics as obs_metrics
from repro.core import mapreduce as mr
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.query import HailQuery

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_kernels.json")

OFFER_RATE = 0.25
QUERY = HailQuery(filter=("visitDate", 7305, 9000), projection=("sourceIP",))


def _stores(blocks: int, rows: int, n_nodes: int):
    _, raw = uservisits_raw(blocks=blocks, rows=rows)
    # timed()'s full-shape warm-up run hits the lru-cached upload pipelines
    # (upload._hail_pipeline), so the measured rep compares compute, not
    # trace+compile
    t_eager, (eager, eager_stats) = timed(
        up.hail_upload, sc.USERVISITS, raw,
        ["visitDate", "sourceIP", "adRevenue"], n_nodes=n_nodes, reps=1)
    t_lazy, (lazy, lazy_stats) = timed(
        up.hail_upload, sc.USERVISITS, raw, index_columns=(),
        replication=3, n_nodes=n_nodes, reps=1)
    return eager, eager_stats, t_eager, lazy, lazy_stats, t_lazy


def convergence(blocks: int = 24, rows: int = 2048,
                offer_rate: float = OFFER_RATE) -> dict:
    # map_slots=1 so convergence also shows HailSplitting's task reduction:
    # indexed blocks coalesce to ONE split per node, full-scan blocks stay
    # one task each — the modeled curve falls as tasks disappear
    cluster = mr.ClusterModel(n_nodes=6, map_slots=1)
    eager, eager_stats, t_eager, lazy, lazy_stats, t_lazy = _stores(
        blocks, rows, cluster.n_nodes)

    base = mr.run_job(eager, QUERY, cluster=cluster)         # warm reader jit
    base = mr.run_job(eager, QUERY, cluster=cluster)
    n_jobs = math.ceil(1 / offer_rate) + 2
    cfg = mr.AdaptiveConfig(offer_rate=offer_rate)
    modeled, e2e, read_mb, built, full_scan, jobs = [], [], [], [], [], []
    for _ in range(n_jobs):
        st = mr.run_job(lazy, QUERY, adaptive=cfg, cluster=cluster)
        assert st.results["n_rows"] == base.results["n_rows"]
        jobs.append(st)
        modeled.append(st.modeled_s)
        e2e.append(st.end_to_end_s)
        read_mb.append(st.bytes_read / 1e6)
        built.append(st.blocks_indexed)
        full_scan.append(st.full_scan_blocks)

    # charge the measured split+build walls to the event-driven scheduler:
    # build-era tasks are honestly slower than converged ones
    from repro.runtime.cluster import SimulatedCluster
    from repro.runtime.scheduler import run_schedule

    def makespan(st):
        sim = SimulatedCluster(n_nodes=cluster.n_nodes,
                               map_slots=cluster.map_slots, seed=0)
        return run_schedule(mr.job_tasks(st), sim, spec_factor=None).makespan_s

    return {
        "offer_rate": offer_rate,
        "jobs_to_converge_model": math.ceil(1 / offer_rate),
        "adaptive_modeled_s": [round(s, 4) for s in modeled],
        "adaptive_e2e_s": [round(s, 4) for s in e2e],
        "adaptive_read_mb": [round(m, 3) for m in read_mb],
        "adaptive_blocks_indexed": built,
        "adaptive_full_scan_blocks": full_scan,
        "adaptive_curve_monotone": all(
            a >= b - 1e-9 for a, b in zip(modeled, modeled[1:])),
        "adaptive_final_modeled_s": round(modeled[-1], 4),
        "adaptive_sched_makespan_first_s": round(makespan(jobs[0]), 4),
        "adaptive_sched_makespan_final_s": round(makespan(jobs[-1]), 4),
        "eager_modeled_s": round(base.modeled_s, 4),
        "adaptive_final_vs_eager": round(modeled[-1] / base.modeled_s, 4),
        "upload_wall_eager_s": round(t_eager, 4),
        "upload_wall_lazy_s": round(t_lazy, 4),
        "upload_lazy_speedup": round(t_eager / t_lazy, 2),
    }


def run(quick: bool = False):
    blocks, rows = (12, 1024) if quick else (24, 2048)
    reg0 = obs_snapshot()
    d = convergence(blocks=blocks, rows=rows)
    # the registry's view of the same run — the convergence loop's
    # hand-collected per-job lists must agree with the job.* counters
    reg = obs_metrics.delta(reg0)
    d["obs_adaptive_blocks_indexed"] = int(obs_sum(reg, "job.blocks_indexed"))
    d["obs_adaptive_jobs"] = int(obs_sum(reg, "job.jobs"))
    d["obs_adaptive_counters_agree"] = (
        d["obs_adaptive_blocks_indexed"]
        == sum(d["adaptive_blocks_indexed"]))

    blob = {}
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as f:
            blob = json.load(f)
    blob.update(d)
    with open(JSON_PATH, "w") as f:
        json.dump(blob, f, indent=1)

    rows_out = [
        ("adaptive_upload_lazy", d["upload_wall_lazy_s"] * 1e6,
         f"eager_us={d['upload_wall_eager_s'] * 1e6:.0f};"
         f"speedup={d['upload_lazy_speedup']:.2f}"),
        ("adaptive_final_job", d["adaptive_final_modeled_s"] * 1e6,
         f"eager_us={d['eager_modeled_s'] * 1e6:.0f};"
         f"ratio={d['adaptive_final_vs_eager']:.3f}"),
        ("adaptive_sched_makespan", d["adaptive_sched_makespan_final_s"] * 1e6,
         f"build_era_us={d['adaptive_sched_makespan_first_s'] * 1e6:.0f}"),
    ]
    for k, (m, fs) in enumerate(zip(d["adaptive_modeled_s"],
                                    d["adaptive_full_scan_blocks"])):
        rows_out.append((f"adaptive_job_{k}", m * 1e6,
                         f"full_scan_blocks={fs};"
                         f"built={d['adaptive_blocks_indexed'][k]}"))
    return rows_out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small store for CI (12x1024 blocks)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=args.quick):
        print(f"{name},{us:.1f},{derived}")
