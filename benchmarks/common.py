"""Shared benchmark plumbing: datasets, stores, timing, the cluster model.

Methodology (documented per EXPERIMENTS.md): compute is MEASURED on this box
(jit-warmed, second run); cluster effects (disk at the paper's 100MB/s,
n-node parallelism, per-task scheduling seconds) are MODELED via
core.mapreduce.ClusterModel.  Ratios between systems are the reproduction
target; absolute seconds are simulation outputs.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import mapreduce as mr
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows
from repro.obs import metrics as obs_metrics

ROWS = 4096
BLOCKS = 40
NODES = 10
CLUSTER = mr.ClusterModel(n_nodes=NODES, map_slots=4)

_cache: dict = {}


def uservisits_raw(blocks: int = BLOCKS, rows: int = ROWS):
    key = ("uv", blocks, rows)
    if key not in _cache:
        cols = sc.gen_uservisits(rows * blocks, seed=0)
        raw = format_rows(sc.USERVISITS, cols, bad_fraction=0.0005)
        _cache[key] = (cols, raw.reshape(blocks, rows, -1))
    return _cache[key]


def synthetic_raw(blocks: int = BLOCKS, rows: int = ROWS):
    key = ("syn", blocks, rows)
    if key not in _cache:
        cols = sc.gen_synthetic(rows * blocks, seed=0)
        raw = format_rows(sc.SYNTHETIC, cols)
        _cache[key] = (cols, raw.reshape(blocks, rows, -1))
    return _cache[key]


def hail_store_uv():
    if "store_uv" not in _cache:
        _, raw = uservisits_raw()
        # warm the jit, then measure
        up.hail_upload(sc.USERVISITS, raw[:2],
                       ["visitDate", "sourceIP", "adRevenue"], n_nodes=NODES)
        _cache["store_uv"] = up.hail_upload(
            sc.USERVISITS, raw, ["visitDate", "sourceIP", "adRevenue"],
            n_nodes=NODES)
    return _cache["store_uv"]


def hdfs_store_uv():
    if "hdfs_uv" not in _cache:
        _, raw = uservisits_raw()
        _cache["hdfs_uv"] = up.hdfs_upload(sc.USERVISITS, raw, n_nodes=NODES)
    return _cache["hdfs_uv"]


def hadooppp_store_uv():
    if "hpp_uv" not in _cache:
        _, raw = uservisits_raw()
        _cache["hpp_uv"] = up.hadooppp_upload(sc.USERVISITS, raw, "sourceIP",
                                              n_nodes=NODES)
    return _cache["hpp_uv"]


def obs_snapshot() -> dict:
    """Registry snapshot for a bench section (collectors included)."""
    return obs_metrics.snapshot()


def obs_sum(delta: dict, name: str) -> float:
    """Sum a registry delta over every label set of one series name —
    ``obs_sum(d, "job.blocks_indexed")`` matches the bare series and every
    ``job.blocks_indexed{...}`` variant.  This (snapshot -> delta ->
    obs_sum) is the idiom that replaces the hand-rolled before/after
    field diffs the bench drivers used to carry."""
    return sum(v for k, v in delta.items()
               if k == name or k.startswith(name + "{"))


def timed(fn, *args, warmup: int = 1, reps: int = 3, **kw):
    """(median wall seconds, result) with jit warm-up."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) else None
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        leaves = jax.tree.leaves(out)
        if leaves:
            jax.block_until_ready(leaves[0])
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def upload_model_seconds(stats: up.UploadStats, n_nodes: int = NODES,
                         disk_bw: float = 100e6, net_bw: float = 125e6,
                         cores: int = 4, cpu_factor: float = 1.0) -> float:
    """Modeled cluster upload wall time.

    The paper's central claim (§2.3): sorting/indexing rides the I/O-bound
    upload pipeline on otherwise-idle CPU ticks.  So per-node compute
    OVERLAPS the disk/network stream: wall = client-net + max(disk, compute).
    Nodes have ``cores`` cores (paper: quad-core Xeons); this box measures
    the compute single-threaded, so per-node compute = wall_s/(nodes*cores).
    Hadoop++'s post-hoc job cannot overlap its extra read+write — charged
    sequentially, as in the paper."""
    net_s = stats.ascii_bytes / net_bw            # client -> pipeline
    disk_s = stats.written_bytes / (disk_bw * n_nodes)
    compute_s = stats.wall_s / (n_nodes * cores * cpu_factor)
    extra_s = stats.extra_read_bytes / (disk_bw * n_nodes)
    return net_s + max(disk_s, compute_s) + extra_s


# The paper's workloads ------------------------------------------------------

BOB_QUERIES = {
    # name: (filter col, lo, hi, projection) — selectivities mirror §6.2
    "Bob-Q1": ("visitDate", 10000, 10155, ("sourceIP",)),            # 3.1e-2
    "Bob-Q2": ("sourceIP", None, None, ("searchWord", "duration", "adRevenue")),  # point
    "Bob-Q3": ("sourceIP", None, None, ("searchWord", "duration", "adRevenue")),  # point+post
    "Bob-Q4": ("adRevenue", 1, 1700, ("searchWord", "duration", "adRevenue")),    # 1.7e-2
    "Bob-Q5": ("adRevenue", 1, 20400, ("searchWord", "duration", "adRevenue")),   # 2.0e-1
}

SYN_QUERIES = {
    "Syn-Q1a": ("attr0", 0, 104857, tuple(f"attr{i}" for i in range(19))),
    "Syn-Q1b": ("attr0", 0, 104857, tuple(f"attr{i}" for i in range(9))),
    "Syn-Q1c": ("attr0", 0, 104857, ("attr1",)),
    "Syn-Q2a": ("attr0", 0, 10485, tuple(f"attr{i}" for i in range(19))),
    "Syn-Q2b": ("attr0", 0, 10485, tuple(f"attr{i}" for i in range(9))),
    "Syn-Q2c": ("attr0", 0, 10485, ("attr1",)),
}


def bob_query(name: str):
    from repro.core.query import HailQuery
    col, lo, hi, proj = BOB_QUERIES[name]
    if lo is None:  # point query on an existing sourceIP
        cols, _ = uservisits_raw()
        v = int(cols["sourceIP"][12345])
        lo = hi = v
    return HailQuery(filter=(col, lo, hi), projection=proj)
