"""Paper Fig 8: node failure at 50% progress; slowdown = (T_f - T_b)/T_b.
Compares Hadoop, HAIL (3 different indexes — failed blocks fall back to
scan) and HAIL-1Idx (same index on all replicas — failover keeps index
scans)."""
from __future__ import annotations

from benchmarks.common import CLUSTER, NODES, bob_query, uservisits_raw
from repro.core import mapreduce as mr
from repro.core import schema as sc
from repro.core import upload as up


def _slowdown(store, query, **kw):
    mr.run_job(store, query, cluster=CLUSTER, **kw)           # warm
    base = mr.run_job(store, query, cluster=CLUSTER, **kw)
    fail = mr.run_job(store, query, cluster=CLUSTER, fail_node_at=0.5, **kw)
    assert fail.results["n_rows"] == base.results["n_rows"]
    slow = (fail.end_to_end_s - base.end_to_end_s) / base.end_to_end_s * 100
    return base, fail, slow


def run():
    rows = []
    query = bob_query("Bob-Q1")
    _, raw = uservisits_raw()

    hdfs, _ = up.hdfs_upload(sc.USERVISITS, raw, n_nodes=NODES)
    b, f, s = _slowdown(hdfs, query)
    rows.append(("fig8_hadoop", f.end_to_end_s * 1e6,
                 f"slowdown_pct={s:.1f};rescheduled={f.rescheduled_tasks}"))

    hail, _ = up.hail_upload(sc.USERVISITS, raw,
                             ["visitDate", "sourceIP", "adRevenue"],
                             n_nodes=NODES)
    b, f, s = _slowdown(hail, query, splitting="hail")
    rows.append(("fig8_hail_3idx", f.end_to_end_s * 1e6,
                 f"slowdown_pct={s:.1f};rescheduled={f.rescheduled_tasks}"))

    one, _ = up.hail_upload(sc.USERVISITS, raw,
                            ["visitDate", "visitDate", "visitDate"],
                            n_nodes=NODES)
    b, f, s = _slowdown(one, query, splitting="hail")
    rows.append(("fig8_hail_1idx", f.end_to_end_s * 1e6,
                 f"slowdown_pct={s:.1f};rescheduled={f.rescheduled_tasks}"))

    # straggler mitigation (beyond-paper runtime feature, same control plane)
    from repro.runtime.cluster import SimulatedCluster
    from repro.runtime.scheduler import Task, run_schedule
    tasks = [Task(i, 5.0, preferred_nodes=(i % 8, (i + 3) % 8))
             for i in range(16)]
    kw = dict(n_nodes=8, map_slots=2, straggler_frac=0.25, straggler_slow=5.0,
              seed=2)
    nospec = run_schedule(tasks, SimulatedCluster(**kw), spec_factor=None)
    spec = run_schedule(tasks, SimulatedCluster(**kw), spec_factor=1.5)
    rows.append(("fig8x_straggler_speculation", spec.makespan_s * 1e6,
                 f"makespan_reduction={nospec.makespan_s / spec.makespan_s:.2f};"
                 f"speculative={spec.n_speculative}"))
    return rows
