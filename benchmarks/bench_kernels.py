"""Pallas kernels: interpret-mode correctness + us/call vs jnp oracle.
(Interpret mode executes the kernel body in Python — timings demonstrate the
harness, not TPU performance; the TPU path flips interpret=False.)

Also records the fused split-reader's DISPATCH and RECOMPILE counts (plus
per-query latency over distinct ranges) to BENCH_kernels.json — the
regression guard for the one-dispatch-per-split / zero-per-query-recompile
properties (see EXPERIMENTS.md)."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels import ref
from repro.kernels.block_sort import bitonic_sort
from repro.kernels.flash_attention import flash_attention
from repro.kernels.index_search import index_search
from repro.kernels.pax_scan import pax_scan

KEY = jax.random.PRNGKey(0)

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_kernels.json")


def reader_dispatch_stats(n_queries: int = 10) -> dict:
    """Run n_queries distinct (lo, hi) ranges through the fused reader on a
    small HAIL store; count dispatches, retraces, and per-query latency."""
    from benchmarks.common import uservisits_raw
    from repro.core import query as q
    from repro.core import schema as sc
    from repro.core import upload as up
    from repro.kernels import ops

    _, raw = uservisits_raw(blocks=8, rows=4096)
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              n_nodes=4)
    qp = q.plan(store, q.HailQuery(filter=("visitDate", 0, 1),
                                   projection=("sourceIP",)))
    ranges = [(7305 + 13 * i, 7670 + 29 * i) for i in range(n_queries)]
    ops.reset_stats()
    lat_us = []
    for lo, hi in ranges:
        query = q.HailQuery(filter=("visitDate", lo, hi),
                            projection=("sourceIP",))
        t0 = time.perf_counter()
        res = q.read_hail_kernels(store, query, qp)
        jax.block_until_ready(res.mask)
        lat_us.append((time.perf_counter() - t0) * 1e6)
    stats = ops.reader_stats()
    return {
        "n_queries": n_queries,
        "n_splits_per_query": 1,
        "dispatches": stats["dispatches"].get("hail_read", 0),
        "recompiles": stats["traces"].get("hail_read", 0),
        "recompiles_after_first": max(
            stats["traces"].get("hail_read", 0) - 1, 0),
        "per_query_latency_us": [round(u, 1) for u in lat_us],
        "first_query_us": round(lat_us[0], 1),
        "steady_state_us": round(
            sorted(lat_us[1:])[len(lat_us[1:]) // 2], 1),
    }


def run():
    rows = []
    keys = jax.random.randint(KEY, (4, 1024), 0, 1 << 20, dtype=jnp.int32)
    t, _ = timed(lambda: bitonic_sort(keys))
    tr, _ = timed(lambda: jax.vmap(ref.sort_by_key)(keys))
    rows.append(("kernel_block_sort_4x1024", t * 1e6, f"ref_us={tr * 1e6:.0f}"))

    mins = jnp.sort(jax.random.randint(KEY, (64, 64), 0, 1 << 20,
                                       dtype=jnp.int32), axis=1)
    t, _ = timed(lambda: index_search(mins, 1000, 100000))
    tr, _ = timed(lambda: ref.index_search(mins, 1000, 100000))
    rows.append(("kernel_index_search_64x64", t * 1e6, f"ref_us={tr * 1e6:.0f}"))

    kc = jax.random.randint(KEY, (8192,), 0, 1 << 20, dtype=jnp.int32)
    pj = jax.random.randint(KEY, (8192, 4), 0, 99, dtype=jnp.int32)
    t, _ = timed(lambda: pax_scan(kc, pj, 0, 1 << 18))
    tr, _ = timed(lambda: ref.pax_scan(kc, pj, 0, 1 << 18))
    rows.append(("kernel_pax_scan_8192x4", t * 1e6, f"ref_us={tr * 1e6:.0f}"))

    q = jax.random.normal(KEY, (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 256, 2, 64))
    t, _ = timed(lambda: flash_attention(q, k, v, block_q=128, block_k=128))
    tr, _ = timed(lambda: ref.attention(q, k, v))
    rows.append(("kernel_flash_attn_256", t * 1e6, f"ref_us={tr * 1e6:.0f}"))

    from repro.kernels.selective_scan import selective_scan
    ks = [jax.random.fold_in(KEY, 10 + i) for i in range(5)]
    delta = jax.nn.softplus(jax.random.normal(ks[0], (1, 64, 32)))
    x2 = jax.random.normal(ks[1], (1, 64, 32))
    b2 = jax.random.normal(ks[2], (1, 64, 8))
    c2 = jax.random.normal(ks[3], (1, 64, 8))
    a2 = -jnp.exp(jax.random.normal(ks[4], (32, 8)) * 0.3)
    t, _ = timed(lambda: selective_scan(delta, x2, b2, c2, a2,
                                        chunk=16, d_block=16))
    tr, _ = timed(lambda: ref.selective_scan(delta, x2, b2, c2, a2))
    rows.append(("kernel_selective_scan_64x32", t * 1e6,
                 f"ref_us={tr * 1e6:.0f}"))

    # fused split reader: dispatch/recompile regression guard -> JSON
    # (merge so bench_query's query_job_latency_us keys survive either order)
    ds = reader_dispatch_stats()
    blob = {}
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as f:
            blob = json.load(f)
    blob.update(ds)
    with open(JSON_PATH, "w") as f:
        json.dump(blob, f, indent=1)
    rows.append(("kernel_hail_read_dispatches", ds["steady_state_us"],
                 f"dispatches={ds['dispatches']};"
                 f"recompiles_after_first={ds['recompiles_after_first']};"
                 f"json={os.path.basename(JSON_PATH)}"))
    return rows
