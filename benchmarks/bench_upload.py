"""Paper Fig 4: upload times vs #indexes (a: UserVisits, b: Synthetic) and
vs replication factor (c)."""
from __future__ import annotations

from benchmarks.common import (NODES, synthetic_raw, upload_model_seconds,
                               uservisits_raw)
from repro.core import schema as sc
from repro.core import upload as up

KEYS = ["visitDate", "sourceIP", "adRevenue", "duration", "searchWord",
        "countryCode"]
SKEYS = [f"attr{i}" for i in range(8)]


def _hail(schema, raw, keys):
    up.hail_upload(schema, raw[:2], keys, n_nodes=NODES)      # warm
    store, stats = up.hail_upload(schema, raw, keys, n_nodes=NODES)
    return stats


def run():
    rows = []
    for tag, (_, raw), schema, keys in (
            ("uservisits", uservisits_raw(), sc.USERVISITS, KEYS),
            ("synthetic", synthetic_raw(), sc.SYNTHETIC, SKEYS)):
        _, h_stats = up.hdfs_upload(schema, raw, n_nodes=NODES)
        base = upload_model_seconds(h_stats)
        rows.append((f"fig4_{tag}_hadoop_0idx", base * 1e6,
                     "speedup_vs_hadoop=1.00"))
        _, pp_stats = up.hadooppp_upload(schema, raw, keys[0], n_nodes=NODES)
        t = upload_model_seconds(pp_stats)
        rows.append((f"fig4_{tag}_hadooppp_1idx", t * 1e6,
                     f"speedup_vs_hadoop={base / t:.2f}"))
        for n_idx in (0, 1, 2, 3):
            ks = keys[:n_idx] + [None] * (3 - n_idx)
            stats = _hail(schema, raw, ks)
            t = upload_model_seconds(stats)
            rows.append((f"fig4_{tag}_hail_{n_idx}idx", t * 1e6,
                         f"speedup_vs_hadoop={base / t:.2f}"))
    # Fig 4c: replication scaling on Synthetic, one index per replica
    _, raw = synthetic_raw()
    _, h_stats = up.hdfs_upload(sc.SYNTHETIC, raw, replication=3, n_nodes=NODES)
    base3 = upload_model_seconds(h_stats)
    for r in (1, 2, 3, 5, 6):
        stats = _hail(sc.SYNTHETIC, raw, SKEYS[:r])
        t = upload_model_seconds(stats)
        rows.append((f"fig4c_hail_repl{r}", t * 1e6,
                     f"vs_hadoop_repl3={base3 / t:.2f};indexes={r}"))
    return rows
