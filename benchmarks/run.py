# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys
import traceback

MODULES = [
    "benchmarks.bench_upload",      # Fig 4(a,b,c)
    "benchmarks.bench_scale",       # Table 2 + Fig 5
    "benchmarks.bench_query",       # Fig 6 + Fig 7
    "benchmarks.bench_failover",    # Fig 8 (+ straggler mitigation)
    "benchmarks.bench_splitting",   # Fig 9
    "benchmarks.bench_adaptive",    # LIAH convergence (lazy -> indexed)
    "benchmarks.bench_governor",    # budget eviction + workload-shift reconvergence
    "benchmarks.bench_server",      # shared-scan serving + hot-block cache
    "benchmarks.bench_kernels",     # Pallas kernel harness
    "benchmarks.bench_roofline",    # roofline table from the dry-run
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = 0
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            failed += 1
            traceback.print_exc(file=sys.stderr)
            print(f"{mod_name},nan,ERROR", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
