"""Concurrent serving bench: shared-scan batching + the hot-block cache.

Q concurrent range queries over a shared replica, served two ways:

* SERIAL — Q independent ``run_job(reader="kernels")`` calls (one fused
  dispatch per split PER QUERY, Q x the scheduling overhead);
* BATCHED — one ``HailServer.flush``: the Q queries form one shared-scan
  batch, one fused dispatch per (split, batch), per-query masks out of the
  kernel.

Reported and regression-guarded in CI:

* dispatch count: batched fused dispatches must be <= the ceil model
  ``ceil(Q / max_batch) * splits_per_job`` (vs ``Q * splits_per_job``
  serial) — and row counts must be identical per query;
* makespan: both sides bridged into ``runtime/scheduler.run_schedule`` with
  the same per-task scheduling constant (EXPERIMENTS.md's Hadoop seconds) —
  the batched makespan must be <= 0.5x serial at Q=8 (it models ~1/Q);
* block cache: a warm re-flush must hit 100% on an unbounded cache; at a
  HALF-working-set budget the scan-resistant admission must keep the
  resident half hot — hit rate strictly > 0 with admission rejects instead
  of the pure-LRU thrash this bench used to document (0.0 hit rate, 186
  evictions: every fill evicted a block needed again before the admitted
  block was ever reused);
* result cache: re-flushing the SAME ranges must be served entirely from
  the materialized-answer tier — zero fused reader dispatches.

The batched and half-budget sections run with ``result_cache=False``: they
measure the scan path itself, which the result cache would short-circuit.

The LATENCY section drives the ``ServerFrontend`` event loop at a fixed
offered load (one query every ``1/load`` simulated seconds) under two
policies: AUTO-FLUSH (a cohort dispatches the moment a compatible batch
fills, or the oldest waiter ages out) versus the SINGLE-BIG-FLUSH baseline
(``window_s=inf``: nothing fires until the end-of-workload drain, so the
first arrival waits out the whole accumulation span).  Per-query latency =
flush trigger time + the query's completion offset in the modeled schedule
(``run_schedule.query_completion_s`` — answers stream as their last live
split finishes) - arrival time.  CI guards that auto-flush p50 AND p99 beat
the single-flush baseline at equal offered load.
"""
from __future__ import annotations

import argparse
import gc
import json
import math
import os
import statistics

from benchmarks.common import obs_snapshot, obs_sum, uservisits_raw
from repro.core import mapreduce as mr
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.cache import BlockCache
from repro.core.query import HailQuery
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime import jobserver as js
from repro.runtime.cluster import SimulatedCluster
from repro.runtime.scheduler import Task, run_schedule

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_kernels.json")

Q = 8
RANGES = [(7305, 7670), (0, 2000), (5000, 20000), (7, 7),
          (123, 9999), (0, 1 << 30), (42, 4242), (1000, 8001)]


def _sched_tasks(durs, builds, rekeys, n_queries, sched_s):
    """Scheduler tasks with the per-task scheduling constant added — the
    same constant on both sides, so the makespan ratio isolates the
    fewer-tasks win (EXPERIMENTS.md, shared-scan model)."""
    return [Task(i, sched_s + d, preferred_nodes=(), index_build_s=b,
                 rekey_s=r, n_queries=nq)
            for i, (d, b, r, nq) in enumerate(zip(durs, builds, rekeys,
                                                  n_queries))]


def shared_scan(blocks: int = 24, rows: int = 2048) -> dict:
    cluster = mr.ClusterModel(n_nodes=6, map_slots=2)
    _, raw = uservisits_raw(blocks=blocks, rows=rows)
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              n_nodes=cluster.n_nodes)
    queries = [HailQuery(filter=("visitDate", lo, hi),
                         projection=("sourceIP",)) for lo, hi in RANGES]
    assert len(queries) == Q

    # --- serial baseline: Q independent jobs (second run = jit-warm) ------
    mr.run_job(store, queries[0], reader="kernels", cluster=cluster)
    with ops.stats_scope() as s_serial:
        serial = [mr.run_job(store, qq, reader="kernels", cluster=cluster)
                  for qq in queries]
    serial_dispatches = s_serial.dispatches["hail_read"]
    serial_tasks = _sched_tasks(
        [d for st in serial for d in st.split_s],
        [b for st in serial for b in st.build_s],
        [r for st in serial for r in (st.demote_s or [0.0] * st.n_tasks)],
        [1] * sum(st.n_tasks for st in serial),
        cluster.hail_sched_overhead_s)

    # --- batched: one flush, one shared-scan batch ------------------------
    server = js.HailServer(store, js.ServerConfig(max_batch=Q,
                                                  cluster=cluster,
                                                  result_cache=False))
    for i, qq in enumerate(queries):
        server.submit(qq, tenant=f"tenant{i % 4}")
    server.flush()                         # cold: compiles the Q-wide reader
    cold_results = [t.result.n_rows for t in server.tickets[:Q]]
    for i, qq in enumerate(queries):
        server.submit(qq, tenant=f"tenant{i % 4}")
    with ops.stats_scope() as s_batch:
        fl = server.flush()                # warm: measured + all cache hits
    batched_dispatches = s_batch.dispatches["hail_read"]
    batched_tasks = _sched_tasks(fl.split_s, fl.build_s, fl.demote_s,
                                 fl.batch_of_split,
                                 cluster.hail_sched_overhead_s)

    # row counts identical, batched or serial, cold or warm
    for st, t, cold in zip(serial, server.tickets[Q:], cold_results):
        assert st.results["n_rows"] == t.result.n_rows == cold

    splits_per_job = serial[0].n_tasks
    dispatch_model = math.ceil(Q / Q) * splits_per_job
    sim = lambda tasks: run_schedule(          # noqa: E731
        tasks, SimulatedCluster(cluster.n_nodes, cluster.map_slots),
        spec_factor=None)
    serial_sched = sim(serial_tasks)
    batched_sched = sim(batched_tasks)
    warm_hit_rate = (fl.cache_hits
                     / max(fl.cache_hits + fl.cache_misses, 1))

    # --- result cache: repeated ranges skip the scan entirely -------------
    rc_server = js.HailServer(store, js.ServerConfig(max_batch=Q,
                                                     cluster=cluster))
    for qq in queries:
        rc_server.submit(qq)
    rc_server.flush()                      # cold: fills the result tier
    for qq in queries:
        rc_server.submit(qq)
    with ops.stats_scope() as s_rc:
        fl_rc = rc_server.flush()          # warm repeat: zero dispatches
    warm_repeat_dispatches = (s_rc.dispatches["hail_read"]
                              + s_rc.dispatches["hail_read_batch"])
    for t, cold in zip(rc_server.tickets[Q:], cold_results):
        assert t.result.from_cache and t.result.n_rows == cold
    rc_hit_rate = (fl_rc.result_cache_hits
                   / max(fl_rc.result_cache_hits
                         + fl_rc.result_cache_misses, 1))

    # --- cache budget sweep: half the working set, scan-resistant ---------
    full_bytes = store.block_cache.stats.bytes_cached
    half = BlockCache(capacity_bytes=max(full_bytes // 2, 1)).attach(store)
    budget_server = js.HailServer(store, js.ServerConfig(
        max_batch=1, cluster=cluster, result_cache=False))
    for _ in range(2):
        for qq in queries:
            budget_server.submit(qq)
        budget_server.flush()
    half_hit_rate = half.stats.hit_rate

    return {
        "server_q": Q,
        "server_blocks": blocks,
        "server_splits_per_job": splits_per_job,
        "server_dispatch_model": dispatch_model,
        "server_serial_dispatches": serial_dispatches,
        "server_batched_dispatches": batched_dispatches,
        "server_batch_sizes": fl.batch_sizes,
        "server_serial_makespan_s": round(serial_sched.makespan_s, 4),
        "server_batched_makespan_s": round(batched_sched.makespan_s, 4),
        "server_makespan_ratio": round(
            batched_sched.makespan_s / serial_sched.makespan_s, 4),
        "server_serial_queries_per_s": round(
            Q / serial_sched.makespan_s, 6),
        "server_batched_queries_per_s": round(
            Q / batched_sched.makespan_s, 6),
        "server_flush_modeled_s": round(fl.modeled_s, 4),
        "server_bytes_read": int(fl.bytes_read),
        "server_cache_hit_rate_warm": round(warm_hit_rate, 4),
        "server_cache_bytes_full": int(full_bytes),
        "server_cache_hit_rate_half_budget": round(half_hit_rate, 4),
        "server_cache_evictions_half_budget": half.stats.evictions,
        "server_cache_admission_rejects_half_budget":
            half.stats.admission_rejects,
        "server_result_cache_hit_rate": round(rc_hit_rate, 4),
        "server_result_cache_entries": len(rc_server.result_cache),
        "server_warm_repeat_dispatches": int(warm_repeat_dispatches),
    }


def trace_overhead(blocks: int = 12, rows: int = 1024,
                   pairs: int = 6, rounds: int = 3) -> dict:
    """The disabled-tracing cost guard (<5% on a warm flush) plus a sanity
    export of the traced flush itself.

    Warm flushes are measured with tracing off and on in STRICT
    ALTERNATION (off, on, off, on, ...), ``pairs`` pairs per round with GC
    paused; each round's estimate is the MEDIAN of the per-pair on/off
    ratios (the pair members are adjacent in time, so container drift
    cancels within a pair), and the guarded ratio is the MIN across
    ``rounds`` independent rounds.  Rationale: the flush wall's noise
    floor in this container is +-5% — the same size as the guard — while
    the true tracer cost is < 1% (cProfile shows no obs frames at all in
    a traced flush), so ANY clean round demonstrates the absence of
    overhead, and a real regression (hooks doing work when disabled)
    would lift every round and still trip the 1.05 guard.  The last
    traced flush's export must also validate against the Chrome
    trace-event contract."""
    cluster = mr.ClusterModel(n_nodes=6, map_slots=2)
    _, raw = uservisits_raw(blocks=blocks, rows=rows)
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              n_nodes=cluster.n_nodes)
    queries = [HailQuery(filter=("visitDate", lo, hi),
                         projection=("sourceIP",)) for lo, hi in RANGES]
    server = js.HailServer(store, js.ServerConfig(max_batch=Q,
                                                  cluster=cluster,
                                                  result_cache=False))

    def one_flush() -> float:
        for qq in queries:
            server.submit(qq)
        return server.flush().wall_s

    one_flush()                      # jit-warm + fill the block cache
    base = traced = float("inf")
    medians = []
    tracer = None
    gc.collect()
    gc.disable()                     # GC pauses are the dominant spike
    try:
        for _ in range(rounds):
            ratios = []
            for _ in range(pairs):
                off = one_flush()
                tracer = obs_trace.install()  # fresh buffer per traced rep
                on = one_flush()
                obs_trace.uninstall()
                ratios.append(on / off if off > 0 else 1.0)
                base, traced = min(base, off), min(traced, on)
            medians.append(statistics.median(ratios))
    finally:
        gc.enable()
    errors = obs_trace.validate_chrome_trace(tracer.export())
    return {
        "obs_trace_base_flush_s": round(base, 6),
        "obs_trace_traced_flush_s": round(traced, 6),
        "obs_trace_overhead_ratio": round(min(medians), 4),
        "obs_trace_round_medians": [round(m, 4) for m in medians],
        "obs_trace_events": len(tracer.events),
        "obs_trace_valid": not errors,
    }


def latency_slo(blocks: int = 12, rows: int = 1024,
                loads: tuple = (2.0, 8.0), n_queries: int = 32) -> dict:
    """p50/p99 serving latency vs offered load: auto-flush frontend against
    the single-big-flush baseline, same arrivals, same store.

    The CI guard reads index 0 — the lowest load, where the accumulation
    span the baseline's first arrival must wait out dominates any plausible
    per-flush service time; higher loads chart how the gap closes as the
    modeled cluster saturates (``busy_until`` queueing)."""
    cluster = mr.ClusterModel(n_nodes=6, map_slots=2)
    _, raw = uservisits_raw(blocks=blocks, rows=rows)
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              n_nodes=cluster.n_nodes)
    reps = (n_queries + len(RANGES) - 1) // len(RANGES)
    queries = [HailQuery(filter=("visitDate", lo, hi),
                         projection=("sourceIP",))
               for lo, hi in (RANGES * reps)[:n_queries]]

    def mk_server():
        # no caches: both policies measure the raw scan path, and repeated
        # ranges must not short-circuit through the result tier
        return js.HailServer(store, js.ServerConfig(
            max_batch=4, max_pending_total=n_queries,
            max_pending_per_tenant=n_queries, cluster=cluster,
            cache=False, result_cache=False))

    warm = mk_server()             # jit-warm the width-4 reader variant
    for qq in queries[:4]:
        warm.submit(qq)
    warm.flush()

    out = {"server_offered_load": list(loads),
           "server_latency_n_queries": n_queries,
           "server_latency_flushes": [],
           "server_latency_p50": [], "server_latency_p99": [],
           "server_latency_p50_single_flush": [],
           "server_latency_p99_single_flush": []}
    for load in loads:
        dt = 1.0 / load
        # cohorts of max_batch arrive inside the window, so the batch-full
        # trigger fires first and every flush is width 4 — ONE compiled
        # reader variant, shared with the jit-warm flush above
        policy = js.FlushPolicy(window_s=4 * dt)

        def drive(policy):
            fe = js.ServerFrontend(mk_server(), policy)
            for k, qq in enumerate(queries):
                fe.offer(qq, at=k * dt)
            fe.drain()
            assert len(fe.latencies) == n_queries and not fe.failed
            return fe

        auto = drive(policy)
        single = drive(js.FlushPolicy(window_s=float("inf")))
        assert len(single.flushes) == 1      # baseline: ONE drain flush
        assert single.flushes[0].n_queries == n_queries
        out["server_latency_flushes"].append(len(auto.flushes))
        out["server_latency_p50"].append(round(auto.percentile_latency(50), 4))
        out["server_latency_p99"].append(round(auto.percentile_latency(99), 4))
        out["server_latency_p50_single_flush"].append(
            round(single.percentile_latency(50), 4))
        out["server_latency_p99_single_flush"].append(
            round(single.percentile_latency(99), 4))
    return out


def run(quick: bool = False):
    blocks, rows = (12, 1024) if quick else (24, 2048)
    reg0 = obs_snapshot()
    d = shared_scan(blocks=blocks, rows=rows)
    d.update(latency_slo(blocks=blocks, rows=rows))
    d.update(trace_overhead(blocks=blocks, rows=rows))
    reg = obs_metrics.delta(reg0)
    d.update({
        "obs_flush_queries": int(obs_sum(reg, "flush.queries")),
        "obs_flush_count": int(obs_sum(reg, "flush.flushes")),
        "obs_flush_result_cache_hits": int(
            obs_sum(reg, "flush.cache_hits{tier=result}")),
        "obs_flush_block_cache_hits": int(
            obs_sum(reg, "flush.cache_hits{tier=block}")),
    })

    blob = {}
    if os.path.exists(JSON_PATH):
        with open(JSON_PATH) as f:
            blob = json.load(f)
    blob.update(d)
    with open(JSON_PATH, "w") as f:
        json.dump(blob, f, indent=1)

    return [
        ("server_batched_flush", d["server_batched_makespan_s"] * 1e6,
         f"dispatches={d['server_batched_dispatches']}"
         f"/model={d['server_dispatch_model']};"
         f"ratio={d['server_makespan_ratio']}"),
        ("server_serial_baseline", d["server_serial_makespan_s"] * 1e6,
         f"dispatches={d['server_serial_dispatches']};q={d['server_q']}"),
        ("server_cache_warm", d["server_cache_hit_rate_warm"],
         f"half_budget_rate={d['server_cache_hit_rate_half_budget']};"
         f"admission_rejects="
         f"{d['server_cache_admission_rejects_half_budget']}"),
        ("server_result_cache", d["server_result_cache_hit_rate"],
         f"warm_repeat_dispatches={d['server_warm_repeat_dispatches']};"
         f"entries={d['server_result_cache_entries']}"),
        ("server_latency_auto_p99", d["server_latency_p99"][0] * 1e6,
         f"p50={d['server_latency_p50'][0]};"
         f"flushes={d['server_latency_flushes'][0]};"
         f"load={d['server_offered_load'][0]}qps"),
        ("server_latency_single_flush_p99",
         d["server_latency_p99_single_flush"][0] * 1e6,
         f"p50={d['server_latency_p50_single_flush'][0]};flushes=1"),
        ("obs_trace_overhead", d["obs_trace_overhead_ratio"],
         f"base_us={d['obs_trace_base_flush_s'] * 1e6:.0f};"
         f"traced_us={d['obs_trace_traced_flush_s'] * 1e6:.0f};"
         f"events={d['obs_trace_events']};valid={d['obs_trace_valid']}"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small store for CI (12x1024 blocks)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, us, derived in run(quick=args.quick):
        print(f"{name},{us:.1f},{derived}")
