"""Paper Fig 9 + §6.5: HailSplitting impact on end-to-end job runtimes.
Uses a block-heavy store (many small blocks) so the per-task scheduling
overhead dominates, as in the paper's 3,200-task jobs."""
from __future__ import annotations

from benchmarks.common import CLUSTER, NODES, bob_query
from repro.core import mapreduce as mr
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows

BLOCKS, ROWS = 160, 1024


def _store():
    cols = sc.gen_uservisits(BLOCKS * ROWS, seed=3)
    raw = format_rows(sc.USERVISITS, cols).reshape(BLOCKS, ROWS, -1)
    up.hail_upload(sc.USERVISITS, raw[:2],
                   ["visitDate", "sourceIP", "adRevenue"], n_nodes=NODES)
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              partition_size=256, n_nodes=NODES)
    hdfs, _ = up.hdfs_upload(sc.USERVISITS, raw, n_nodes=NODES)
    return store, hdfs


def run():
    rows = []
    store, hdfs = _store()
    total_speedups = {"hail": [], "hadoop": []}
    for name in ("Bob-Q1", "Bob-Q2", "Bob-Q4", "Bob-Q5"):
        query = bob_query(name)
        mr.run_job(store, query, splitting="hail", cluster=CLUSTER)  # warm
        on = mr.run_job(store, query, splitting="hail", cluster=CLUSTER)
        off = mr.run_job(store, query, splitting="hadoop", cluster=CLUSTER)
        had = mr.run_job(hdfs, query, cluster=CLUSTER)
        assert on.results["n_rows"] == off.results["n_rows"] == had.results["n_rows"]
        rows.append((f"fig9_{name}_hailsplit_on", on.end_to_end_s * 1e6,
                     f"tasks={on.n_tasks};speedup_vs_hadoop="
                     f"{had.end_to_end_s / on.end_to_end_s:.1f}"))
        rows.append((f"fig9_{name}_hailsplit_off", off.end_to_end_s * 1e6,
                     f"tasks={off.n_tasks};speedup_vs_hadoop="
                     f"{had.end_to_end_s / off.end_to_end_s:.1f}"))
        rows.append((f"fig9_{name}_hadoop", had.end_to_end_s * 1e6,
                     f"tasks={had.n_tasks}"))
        total_speedups["hail"].append(had.end_to_end_s / on.end_to_end_s)
        total_speedups["hadoop"].append(1.0)
    import numpy as np
    rows.append(("fig9c_workload_geomean_speedup",
                 0.0,
                 f"hail_vs_hadoop={np.exp(np.mean(np.log(total_speedups['hail']))):.1f}x"))
    return rows
