"""Paper Fig 6 (Bob workload) + Fig 7 (Synthetic selectivities): end-to-end
job runtimes, RecordReader times, framework overhead.  HailSplitting is
DISABLED here (paper §6.4 isolates index benefits; §6.5 re-enables it —
see bench_splitting).  Also measures per-query latency across DISTINCT
ranges with hail splitting — the zero-per-query-recompile property: the
seed baked (lo, hi) into jit statics and retraced every range; now only
the first query pays compilation.  Latencies land in BENCH_kernels.json."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import (BLOCKS, CLUSTER, NODES, SYN_QUERIES, bob_query,
                               hadooppp_store_uv, hail_store_uv, hdfs_store_uv,
                               synthetic_raw)
from repro.core import mapreduce as mr
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.query import HailQuery


def _job(store, query, warm: bool = True, **kw):
    if warm:
        mr.run_job(store, query, cluster=CLUSTER, **kw)
    return mr.run_job(store, query, cluster=CLUSTER, **kw)


def run():
    rows = []
    hail, _ = hail_store_uv()
    hdfs, _ = hdfs_store_uv()
    hpp, _ = hadooppp_store_uv()
    for name in ("Bob-Q1", "Bob-Q2", "Bob-Q3", "Bob-Q4", "Bob-Q5"):
        query = bob_query(name)
        jh = _job(hdfs, query)
        jp = _job(hpp, query, splitting="hadoop")
        ja = _job(hail, query, splitting="hadoop")   # splitting disabled
        rows.append((f"fig6_{name}_hadoop", jh.end_to_end_s * 1e6,
                     f"rr_us={jh.record_reader_s * 1e6:.0f};speedup=1.00"))
        rows.append((f"fig6_{name}_hadooppp", jp.end_to_end_s * 1e6,
                     f"rr_us={jp.record_reader_s * 1e6:.0f};"
                     f"speedup={jh.end_to_end_s / jp.end_to_end_s:.2f}"))
        rows.append((f"fig6_{name}_hail", ja.end_to_end_s * 1e6,
                     f"rr_us={ja.record_reader_s * 1e6:.0f};"
                     f"speedup={jh.end_to_end_s / ja.end_to_end_s:.2f};"
                     f"rr_speedup={jh.record_reader_s / ja.record_reader_s:.1f}"))
        # Fig 6c: framework overhead fraction
        ov = ja.overhead_s / (CLUSTER.n_nodes * CLUSTER.map_slots)
        rows.append((f"fig6c_{name}_hail_overhead", ov * 1e6,
                     f"overhead_frac={ov / ja.end_to_end_s:.2f}"))

    # Fig 7: Synthetic — all queries filter attr0; HAIL indexes attr0/1/2
    _, raw = synthetic_raw()
    up.hail_upload(sc.SYNTHETIC, raw[:2], ["attr0", "attr1", "attr2"],
                   n_nodes=NODES)
    syn_store, _ = up.hail_upload(sc.SYNTHETIC, raw,
                                  ["attr0", "attr1", "attr2"], n_nodes=NODES)
    syn_hdfs, _ = up.hdfs_upload(sc.SYNTHETIC, raw, n_nodes=NODES)
    spp, _ = up.hadooppp_upload(sc.SYNTHETIC, raw, "attr0", n_nodes=NODES)
    for name, (col, lo, hi, proj) in SYN_QUERIES.items():
        query = HailQuery(filter=(col, lo, hi), projection=proj)
        jh = _job(syn_hdfs, query)
        jp = _job(spp, query, splitting="hadoop")
        ja = _job(syn_store, query, splitting="hadoop")
        rows.append((f"fig7_{name}_hadoop", jh.end_to_end_s * 1e6,
                     f"rr_us={jh.record_reader_s * 1e6:.0f}"))
        rows.append((f"fig7_{name}_hadooppp", jp.end_to_end_s * 1e6,
                     f"rr_us={jp.record_reader_s * 1e6:.0f}"))
        rows.append((f"fig7_{name}_hail", ja.end_to_end_s * 1e6,
                     f"rr_us={ja.record_reader_s * 1e6:.0f};"
                     f"speedup={jh.end_to_end_s / ja.end_to_end_s:.2f}"))

    # Per-query latency, 10 DISTINCT ranges, hail splitting (index-scan
    # splits): cold first query includes the one-time reader compile; every
    # later range reuses it (the seed recompiled per range).
    lat = []
    for i in range(10):
        query = HailQuery(filter=("visitDate", 7305 + 13 * i, 7670 + 29 * i),
                          projection=("sourceIP",))
        t0 = time.perf_counter()
        mr.run_job(hail, query, cluster=CLUSTER)
        lat.append((time.perf_counter() - t0) * 1e6)
    steady = sorted(lat[1:])[len(lat[1:]) // 2]
    rows.append(("query_latency_distinct_ranges", steady,
                 f"first_us={lat[0]:.0f};p50_warm_us={steady:.0f};"
                 f"compile_amortized={lat[0] / max(steady, 1e-9):.1f}x"))
    jpath = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_kernels.json")
    blob = {}
    if os.path.exists(jpath):
        with open(jpath) as f:
            blob = json.load(f)
    blob["query_job_latency_us"] = [round(u, 1) for u in lat]
    blob["query_job_steady_state_us"] = round(steady, 1)
    with open(jpath, "w") as f:
        json.dump(blob, f, indent=1)
    return rows
