"""End-to-end behaviour: Bob's exploratory session (paper §1) and the
HAIL-fed training loop — the two flagship flows of the system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc


def test_bobs_exploratory_session(hail_store, oracle_rows):
    """Bob runs Q1 (visitDate), pivots to Q2 (sourceIP), then Q3 (adRevenue)
    — each hits a DIFFERENT per-replica index; every result matches the
    oracle; every job ran as index scans (the paper's whole point)."""
    cols, bad = oracle_rows
    sessions = [
        (("visitDate", 7305, 7670), "sourceIP"),
        (("sourceIP", 0, 2**28), "visitDate"),
        (("adRevenue", 1, 10_000), "searchWord"),
    ]
    used_replicas = set()
    for flt, proj in sessions:
        query = q.HailQuery(filter=flt, projection=(proj,))
        qp = q.plan(hail_store, query)
        assert qp.index_scan.all(), f"{flt[0]} should index-scan"
        used_replicas.add(int(qp.replica_for_block[0]))
        res = q.read_hail(hail_store, query, qp)
        got = np.sort(q.collect(res)[proj])
        m = (cols[flt[0]] >= flt[1]) & (cols[flt[0]] <= flt[2]) & ~bad
        np.testing.assert_array_equal(got, np.sort(cols[proj][m]))
    assert len(used_replicas) == 3      # three different clustered indexes


def test_hail_annotation_syntax(hail_store):
    query = q.hail_annotation(sc.USERVISITS,
                              filter="@3 between(7305,7670)",
                              projection="{@1}")
    assert query.filter == ("visitDate", 7305, 7670)
    assert query.projection == ("sourceIP",)
    point = q.hail_annotation(sc.USERVISITS, filter="@1 = 42",
                              projection="{@3,@9}")
    assert point.filter == ("sourceIP", 42, 42)
    assert point.projection == ("visitDate", "duration")


def test_hail_splitting_reduces_dispatches(hail_store):
    query = q.HailQuery(filter=("visitDate", 7305, 7670),
                        projection=("sourceIP",))
    cluster = mr.ClusterModel(n_nodes=6, map_slots=1)
    a = mr.run_job(hail_store, query, splitting="hail", cluster=cluster)
    b = mr.run_job(hail_store, query, splitting="hadoop", cluster=cluster)
    assert a.n_tasks <= b.n_tasks
    assert a.results["n_rows"] == b.results["n_rows"]


def test_train_on_hail_selected_data():
    """The full loop: build corpus -> indexed selection -> train 10 steps."""
    from repro.configs import get_reduced
    from repro.data.pipeline import CorpusConfig, HailDataSource, build_corpus
    from repro.train.optimizer import OptCfg
    from repro.train.step import init_train_state, make_train_step

    ccfg = CorpusConfig(n_docs=256, seq_width=32, rows_per_block=64,
                        partition_size=32, vocab=512)
    store, _ = build_corpus(ccfg, seed=1)
    src = HailDataSource(store, ccfg, select=("quality", 250, 1000),
                         batch_size=4)
    assert src.used_index

    cfg = get_reduced("llama3.2-1b")
    opt = OptCfg(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    it = iter(src)
    losses = []
    for _ in range(10):
        state, metrics = step(state, next(it))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    # uniform-random tokens: loss must stay pinned near ln(vocab) (stable
    # optimization), starting from ~ln(512)=6.24
    assert abs(losses[-1] - np.log(ccfg.vocab)) < 0.5, losses
