"""Per-kernel allclose vs pure-jnp oracles: shape/dtype sweeps + hypothesis,
plus end-to-end kernel-backed record-reader equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.block_sort import bitonic_sort
from repro.kernels.flash_attention import flash_attention
from repro.kernels.index_search import index_search
from repro.kernels.pax_scan import pax_scan

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# block_sort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("blocks,n", [(1, 64), (4, 256), (2, 1024)])
def test_bitonic_sort_shapes(blocks, n):
    keys = jax.random.randint(KEY, (blocks, n), -1000, 1000, dtype=jnp.int32)
    sk, perm = bitonic_sort(keys)
    np.testing.assert_array_equal(np.asarray(sk), np.sort(np.asarray(keys), 1))
    # perm is a valid permutation reproducing the sort
    np.testing.assert_array_equal(
        np.asarray(jnp.take_along_axis(keys, perm, 1)), np.asarray(sk))
    assert (np.sort(np.asarray(perm), 1) == np.arange(n)).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([32, 128]))
def test_bitonic_sort_property(seed, n):
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(-5, 5, (2, n)).astype(np.int32))  # many ties
    sk, perm = bitonic_sort(keys)
    np.testing.assert_array_equal(np.asarray(sk), np.sort(np.asarray(keys), 1))


# ---------------------------------------------------------------------------
# index_search
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("blocks,parts", [(3, 8), (16, 32), (5, 64)])
def test_index_search_shapes(blocks, parts):
    mins = jnp.sort(jax.random.randint(KEY, (blocks, parts), 0, 10_000,
                                       dtype=jnp.int32), axis=1)
    got = index_search(mins, 500, 7000)
    want = ref.index_search(mins, 500, 7000)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(0, 10_000), st.integers(0, 2**31 - 1))
def test_index_search_property(lo, hi, seed):
    lo, hi = min(lo, hi), max(lo, hi)
    r = np.random.default_rng(seed)
    mins = jnp.asarray(np.sort(r.integers(0, 10_000, (4, 16)), 1).astype(np.int32))
    got = np.asarray(index_search(mins, lo, hi))
    want = np.asarray(ref.index_search(mins, lo, hi))
    np.testing.assert_array_equal(got, want)
    # semantic: returned row range covers every qualifying row
    for b in range(4):
        lo_r, hi_r = got[b]
        assert lo_r <= hi_r


# ---------------------------------------------------------------------------
# pax_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,cols,tile", [(512, 1, 128), (1024, 4, 256),
                                            (2048, 3, 1024)])
def test_pax_scan_shapes(rows, cols, tile):
    kc = jax.random.randint(KEY, (rows,), 0, 1000, dtype=jnp.int32)
    pj = jax.random.randint(KEY, (rows, cols), 0, 99, dtype=jnp.int32)
    m, o, c = pax_scan(kc, pj, 200, 700, row_tile=tile)
    rm, ro, rc = ref.pax_scan(kc, pj, 200, 700)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(rm))
    np.testing.assert_array_equal(np.asarray(o), np.asarray(ro))
    assert int(c.sum()) == int(rc)


def test_pax_scan_dtypes():
    kc = jax.random.randint(KEY, (256,), 0, 1000, dtype=jnp.int32)
    for dt in (jnp.int32, jnp.float32):
        pj = jax.random.randint(KEY, (256, 2), 0, 99).astype(dt)
        m, o, c = pax_scan(kc, pj, 0, 500, row_tile=128)
        rm, ro, rc = ref.pax_scan(kc, pj, 0, 500)
        np.testing.assert_array_equal(np.asarray(o), np.asarray(ro))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,s,h,kv,d", [(128, 128, 4, 4, 32),
                                        (256, 256, 4, 2, 64),
                                        (128, 256, 8, 2, 32)])
def test_flash_attention_shapes(t, s, h, kv, d):
    q = jax.random.normal(KEY, (2, t, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, s, kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, s, kv, d))
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [None, 32, 128])
def test_flash_attention_masks(window):
    q = jax.random.normal(KEY, (1, 256, 2, 32), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (1, 256, 2, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 256, 2, 32))
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    want = ref.attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(KEY, (1, 128, 4, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 128, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (1, 128, 2, 64), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)


# ---------------------------------------------------------------------------
# selective_scan (fused mamba1 recurrence)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,d,n,chunk,dblk", [(32, 16, 8, 8, 8),
                                              (64, 32, 4, 16, 16),
                                              (48, 8, 8, 16, 8)])
def test_selective_scan_shapes(t, d, n, chunk, dblk):
    from repro.kernels.selective_scan import selective_scan
    ks = [jax.random.fold_in(KEY, i) for i in range(5)]
    delta = jax.nn.softplus(jax.random.normal(ks[0], (2, t, d), jnp.float32))
    x = jax.random.normal(ks[1], (2, t, d), jnp.float32)
    b = jax.random.normal(ks[2], (2, t, n), jnp.float32)
    c = jax.random.normal(ks[3], (2, t, n), jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[4], (d, n), jnp.float32) * 0.3)
    y, h = selective_scan(delta, x, b, c, a, chunk=chunk, d_block=dblk)
    ry, rh = ref.selective_scan(delta, x, b, c, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ry),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(rh),
                               atol=1e-4, rtol=1e-4)


def test_selective_scan_matches_mamba1_layer_math():
    """The kernel computes the same recurrence the mamba1 layer uses."""
    from repro.kernels.selective_scan import selective_scan
    from repro.models import mamba as mb
    t, d, n = 16, 8, 4
    delta = jax.nn.softplus(jax.random.normal(KEY, (1, t, d)))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (1, t, d))
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (1, t, n))
    c = jax.random.normal(jax.random.fold_in(KEY, 3), (1, t, n))
    a = -jnp.exp(jnp.zeros((d, n)))
    aa = jnp.exp(delta[..., None] * a)
    bb = (delta * x)[..., None] * b[:, :, None, :]
    h_all = mb._m1_scan_chunk(jnp.zeros((1, d, n)), aa, bb)
    want = jnp.einsum("btdn,btn->btd", h_all, c)
    got, _ = selective_scan(delta, x, b, c, a, chunk=8, d_block=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# end-to-end: kernel-backed record reader == jnp record reader
# ---------------------------------------------------------------------------


def test_record_reader_kernel_equivalence(hail_store):
    from repro.core import query as q
    query = q.HailQuery(filter=("visitDate", 7305, 7670),
                        projection=("sourceIP",))
    qp = q.plan(hail_store, query)
    a = q.read_hail(hail_store, query, qp)
    b = q.read_hail_kernels(hail_store, query, qp)
    am = np.asarray(a.mask)
    bm = np.asarray(b.mask)
    np.testing.assert_array_equal(am, bm)
    np.testing.assert_array_equal(np.asarray(a.cols["sourceIP"])[am],
                                  np.asarray(b.cols["sourceIP"])[bm])
