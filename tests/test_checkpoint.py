"""Checkpoint durability: roundtrip, corruption fallback, atomicity, elastic
resharding, async saver."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ck
from repro.configs import get_reduced
from repro.train.optimizer import OptCfg
from repro.train.step import init_train_state, train_state_specs

KEY = jax.random.PRNGKey(3)


def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,))},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    st = _state()
    ck.save(st, str(tmp_path), 7)
    got, step = ck.restore_latest(str(tmp_path), st)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_corruption_falls_back_to_previous(tmp_path):
    st = _state()
    ck.save(st, str(tmp_path), 1)
    st2 = jax.tree.map(lambda x: x + 1, st)
    d2 = ck.save(st2, str(tmp_path), 2)
    # corrupt step 2
    victim = next(f for f in os.listdir(d2) if f.endswith(".npy"))
    with open(os.path.join(d2, victim), "r+b") as f:
        f.seek(40)
        f.write(b"\xff\xff\xff\xff")
    got, step = ck.restore_latest(str(tmp_path), st)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["params"]["b"]),
                                  np.asarray(st["params"]["b"]))


def test_atomicity_tmp_never_published(tmp_path):
    st = _state()
    ck.save(st, str(tmp_path), 3)
    assert ck.list_steps(str(tmp_path)) == [3]
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ck.list_steps(str(tmp_path)) == [3]       # tmp dirs invisible
    got, step = ck.restore_latest(str(tmp_path), st)
    assert step == 3


def test_async_saver(tmp_path):
    st = _state()
    saver = ck.AsyncSaver()
    saver.save(st, str(tmp_path), 5)
    saver.wait()
    got, step = ck.restore_latest(str(tmp_path), st)
    assert step == 5


def test_elastic_reshard_restore(tmp_path):
    """Save a real train state; restore with specs+mesh placement (the
    elastic path used when the data-parallel degree changes)."""
    from repro.launch.mesh import make_host_mesh
    cfg = get_reduced("llama3.2-1b")
    opt = OptCfg()
    state = init_train_state(cfg, opt, KEY)
    ck.save(state, str(tmp_path), 11)
    specs = train_state_specs(cfg, opt)
    mesh = make_host_mesh()
    got, step = ck.restore_latest(str(tmp_path), state, specs=specs, mesh=mesh)
    assert step == 11
    a = jax.tree.leaves(state["params"])[0]
    b = jax.tree.leaves(got["params"])[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert b.sharding.mesh.shape == {"data": 1, "model": 1}


def _save_three(tmp_path):
    """Steps 1..3, values offset by the step number; returns the dirs."""
    st = _state()
    dirs = {}
    for step in (1, 2, 3):
        sti = jax.tree.map(lambda x, s=step: x + s, st)
        dirs[step] = ck.save(sti, str(tmp_path), step)
    return st, dirs


def test_torn_manifest_skips_to_previous_step(tmp_path):
    """A crash mid-manifest-write (torn JSON) must not wedge restore: the
    step is unverifiable and the previous good step is restored."""
    st, dirs = _save_three(tmp_path)
    mpath = os.path.join(dirs[3], "manifest.json")
    raw = open(mpath, "rb").read()
    with open(mpath, "wb") as f:
        f.write(raw[:len(raw) // 2])          # torn: half-written JSON
    got, step = ck.restore_latest(str(tmp_path), st)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(st["params"]["w"]) + 2)


def test_truncated_leaf_skips_to_previous_step(tmp_path):
    """A leaf file cut short (partial write / disk-full) fails its CRC —
    even though the manifest itself is intact."""
    st, dirs = _save_three(tmp_path)
    victim = next(f for f in sorted(os.listdir(dirs[3]))
                  if f.endswith(".npy"))
    p = os.path.join(dirs[3], victim)
    os.truncate(p, os.path.getsize(p) // 2)
    got, step = ck.restore_latest(str(tmp_path), st)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(got["params"]["b"]),
                                  np.asarray(st["params"]["b"]) + 2)


def test_bad_manifest_crc_skips_newest_verifiable(tmp_path):
    """A wrong stored CRC (bit rot in the manifest) poisons its step; a
    SECOND corrupt step underneath must also be skipped — restore lands on
    the newest step that actually verifies end to end."""
    st, dirs = _save_three(tmp_path)
    import json
    mpath = os.path.join(dirs[3], "manifest.json")
    man = json.load(open(mpath))
    fn = sorted(man["leaves"])[0]
    man["leaves"][fn]["crc32"] ^= 0xFFFFFFFF  # stored CRC no longer matches
    json.dump(man, open(mpath, "w"))
    victim = next(f for f in sorted(os.listdir(dirs[2]))
                  if f.endswith(".npy"))
    with open(os.path.join(dirs[2], victim), "r+b") as f:
        f.seek(16)
        f.write(b"\x5a\x5a\x5a\x5a")          # step 2 rots too
    got, step = ck.restore_latest(str(tmp_path), st)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["step"]),
                                  np.asarray(st["step"]) + 1)
