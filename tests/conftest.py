"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real single
CPU device (the 512-device override belongs to launch/dryrun.py only)."""
import sys

import numpy as np
import pytest

try:  # hypothesis is optional in this container; fall back to the stub
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows

ROWS = 1024
BLOCKS = 4
PART = 128


@pytest.fixture(scope="session")
def uservisits_raw():
    cols = sc.gen_uservisits(ROWS * BLOCKS, seed=7)
    raw = format_rows(sc.USERVISITS, cols, bad_fraction=0.002)
    return cols, raw.reshape(BLOCKS, ROWS, -1)


@pytest.fixture(scope="session")
def hail_store(uservisits_raw):
    _, raw = uservisits_raw
    store, stats = up.hail_upload(
        sc.USERVISITS, raw, ["visitDate", "sourceIP", "adRevenue"],
        partition_size=PART, n_nodes=6)
    return store


@pytest.fixture(scope="session")
def hdfs_store(uservisits_raw):
    _, raw = uservisits_raw
    store, _ = up.hdfs_upload(sc.USERVISITS, raw, replication=3, n_nodes=6)
    return store


@pytest.fixture(scope="session")
def oracle_rows(uservisits_raw):
    """Ground truth rows excluding bad (corrupted) records."""
    import jax
    from repro.core.parse import parse_block
    cols, raw = uservisits_raw
    bad = np.asarray(jax.jit(jax.vmap(
        lambda r: parse_block(sc.USERVISITS, r)[1]))(raw)).reshape(-1)
    return cols, bad
