"""Flight recorder (ISSUE 9): the unified metrics registry, simulated-clock
span tracing with Chrome trace-event (Perfetto) export, and per-query
``Ticket.explain()``.

Acceptance scenario: on a traced flush, ``explain()`` must account for
>= 95% of modeled end-to-end latency (vs ``query_completion_s``) for a
cold query, a result-cache hit, and a mid-flush quarantine survivor; the
exported trace must validate against the Chrome trace-event contract; and
the reader-counter registry audit auto-discovers every ``reader_stats``
key and proves ``reset_stats`` zeroes it and nested ``stats_scope``
scopes merge it.
"""
import doctest
import json

import numpy as np
import pytest

from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.fault import FaultInjector
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime import jobserver as js
from repro.runtime.cluster import SimulatedCluster
from repro.runtime.scheduler import run_schedule

from conftest import PART

CLUSTER = mr.ClusterModel(n_nodes=6, map_slots=2)
# (0, 1<<30) is live on EVERY split; (7305, 7670) prunes to a few blocks
EXPLAIN_RANGES = [(0, 1 << 30), (7305, 7670), (42, 4242), (1000, 8001)]
EXPLAIN_QUERIES = [q.HailQuery(filter=("visitDate", lo, hi),
                               projection=("sourceIP",))
                   for lo, hi in EXPLAIN_RANGES]


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Every test starts and ends untraced (install/uninstall is global)."""
    obs_trace.uninstall()
    yield
    obs_trace.uninstall()


@pytest.fixture()
def obs_store(uservisits_raw):
    """Fresh eager store per test — flushes attach caches, tests corrupt."""
    _, raw = uservisits_raw
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              partition_size=PART, n_nodes=6)
    return store


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_doctests():
    results = doctest.testmod(obs_metrics)
    assert results.attempted > 0 and results.failed == 0


def test_registry_instruments_and_delta():
    reg = obs_metrics.MetricsRegistry()
    reg.inc("reads", 2, tenant="a")
    reg.inc("reads", 3, tenant="a")
    reg.inc("reads", 1, tenant="b")
    reg.gauge("depth").set(7)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.observe("wall_s", v)
    snap = reg.snapshot()
    assert snap["reads{tenant=a}"] == 5 and snap["reads{tenant=b}"] == 1
    assert snap["depth"] == 7
    assert snap["wall_s.count"] == 4 and snap["wall_s.sum"] == 10.0
    assert snap["wall_s.min"] == 1.0 and snap["wall_s.max"] == 4.0
    h = reg.histogram("wall_s")
    assert h.percentile(50) == 2.0 and h.mean == 2.5
    # delta: only what moved
    reg.inc("reads", 4, tenant="b")
    d = reg.delta(snap)
    assert d["reads{tenant=b}"] == 4 and d["reads{tenant=a}"] == 0
    # counters are monotone; kind clashes are typed bugs
    with pytest.raises(ValueError):
        reg.counter("reads", tenant="a").inc(-1)
    with pytest.raises(TypeError):
        reg.gauge("reads", tenant="a")


def test_register_store_collector(obs_store):
    reg = obs_metrics.MetricsRegistry()
    col = obs_metrics.register_store(obs_store, reg)
    snap = reg.snapshot()
    assert snap["store.version"] == obs_store.version
    assert (snap["store.total_indexed_blocks"]
            == obs_store.total_indexed_blocks())
    obs_store.demote_replica(2)
    snap2 = reg.snapshot()
    assert snap2["store.version"] == obs_store.version > snap["store.version"]
    assert (snap2["store.total_indexed_blocks"]
            < snap["store.total_indexed_blocks"])
    reg.unregister_collector(col)
    obs_store.demote_replica(1)
    assert reg.snapshot()["store.version"] == snap2["store.version"]


# ---------------------------------------------------------------------------
# satellite: reader-counter registry completeness audit
# ---------------------------------------------------------------------------


def _reader_series(key: str) -> str:
    name, labels = obs_metrics.parse_reader_key(key)
    return (f"reader.{name}{{column={labels['column']}}}" if labels
            else f"reader.{name}")


def _exercise(store):
    """Touch as many distinct reader counters as one workload can: fused
    single + batched reads (verify on fill), a quarantine, a repair."""
    mr.run_job(store, EXPLAIN_QUERIES[0], reader="kernels", cluster=CLUSTER)
    server = js.HailServer(store, js.ServerConfig(
        max_batch=2, cluster=CLUSTER, cache=False, result_cache=False))
    for qq in EXPLAIN_QUERIES[:2]:
        server.submit(qq)
    server.flush()
    store.quarantine_block(1, 0)
    store.repair_blocks()


def test_reader_counter_registry_audit(obs_store):
    """AUTO-DISCOVER every reader_stats key the workload produces; each one
    must (a) be mirrored by the registry's reader collector, (b) read 0
    after ``reset_stats`` — in the source AND in the registry (no stale
    gauges), (c) merge exactly across nested ``stats_scope`` blocks."""
    ops.reset_stats()
    _exercise(obs_store)
    discovered = {k: v for k, v in ops.reader_stats()["dispatches"].items()
                  if v}
    assert len(discovered) >= 8, f"workload too narrow: {discovered}"
    assert "hail_read" in discovered and "verify_blocks" in discovered
    assert "blocks_quarantined" in discovered
    assert any(k.startswith("index_scan_blocks[") for k in discovered)

    # (a) registry mirrors every discovered key, per-column labels parsed
    snap = obs_metrics.snapshot()
    for key, v in discovered.items():
        assert snap[_reader_series(key)] == v, key

    # (b) reset zeroes the source and the mirrored gauges
    ops.reset_stats()
    after = ops.reader_stats()["dispatches"]
    assert all(after.get(k, 0) == 0 for k in discovered)
    snap0 = obs_metrics.snapshot()
    for key in discovered:
        assert snap0[_reader_series(key)] == 0, key

    # (c) nested scopes merge: outer totals == pre-inner + inner, per key
    with ops.stats_scope(merge=False) as outer:
        _exercise(obs_store)
        solo = dict(ops.reader_stats()["dispatches"])
        with ops.stats_scope() as inner:
            _exercise(obs_store)
    for k in set(solo) | set(inner.dispatches):
        assert (outer.dispatches[k]
                == solo.get(k, 0) + inner.dispatches[k]), k
    # merge=False: the scopes' counts never reach the module globals
    assert all(v == 0 for v in ops.reader_stats()["dispatches"].values())


def test_observe_flush_and_job_series(obs_store):
    before = obs_metrics.snapshot()
    st = mr.run_job(obs_store, EXPLAIN_QUERIES[1], cluster=CLUSTER)
    server = js.HailServer(obs_store, js.ServerConfig(max_batch=4,
                                                      cluster=CLUSTER))
    for qq in EXPLAIN_QUERIES:
        server.submit(qq, tenant="alice")
    fl = server.flush()
    d = obs_metrics.delta(before)
    assert d["job.jobs"] == 1 and d["job.tasks"] == st.n_tasks
    assert d["job.bytes_read"] == st.bytes_read
    assert d["flush.flushes"] == 1 and d["flush.queries"] == fl.n_queries
    assert d["flush.splits"] == fl.n_splits
    assert d["flush.tenant_queries{tenant=alice}"] == len(EXPLAIN_QUERIES)
    assert d["flush.cache_misses{tier=result}"] == fl.result_cache_misses
    assert d["flush.query_done_s.count"] == len(fl.query_done_s)


# ---------------------------------------------------------------------------
# span tracing + Chrome trace-event contract
# ---------------------------------------------------------------------------


def test_traced_flush_exports_valid_chrome_trace(obs_store, tmp_path):
    tracer = obs_trace.install()
    server = js.HailServer(obs_store, js.ServerConfig(max_batch=4,
                                                      cluster=CLUSTER))
    fe = js.ServerFrontend(server, js.FlushPolicy(window_s=0.5))
    for k, qq in enumerate(EXPLAIN_QUERIES):
        fe.offer(qq, tenant=f"t{k % 2}", at=k * 0.3)
    fe.drain()
    obs_trace.uninstall()

    path = tmp_path / "trace.json"
    exported = tracer.export(str(path))
    assert obs_trace.validate_chrome_trace(exported) == []
    with open(path) as f:
        assert obs_trace.validate_chrome_trace(json.load(f)) == []

    evs = exported["traceEvents"]
    names = {e["name"] for e in evs}
    # flush lifecycle on the measured wall
    assert {"flush", "plan", "result_cache_probe", "batching", "split",
            "verify_blocks", "finalize"} <= names
    # simulated timeline: scheduler node tracks + per-tenant query slices
    sim_tracks = {e["args"]["name"] for e in evs
                  if e["ph"] == "M" and e["name"] == "thread_name"
                  and e["pid"] == obs_trace.PID_SIM}
    assert any(t.startswith("node ") for t in sim_tracks)
    assert any(t.startswith("tenant ") for t in sim_tracks)
    # flow arrows connect query slices to the splits they waited on
    flows = [e for e in evs if e["ph"] in ("s", "t", "f")]
    assert {e["ph"] for e in flows} >= {"s", "f"}
    started = {e["id"] for e in flows if e["ph"] == "s"}
    finished = {e["id"] for e in flows if e["ph"] == "f"}
    assert finished and finished <= started


def test_trace_validator_rejects_malformed():
    def errs(events):
        return obs_trace.validate_chrome_trace({"traceEvents": events})

    ok = {"ph": "i", "pid": 1, "tid": 1, "name": "x", "ts": 1.0, "s": "t"}
    assert errs([ok]) == []
    assert errs([{**ok, "ph": "Z"}])                  # unknown phase
    assert errs([{**ok, "ts": -1.0}])                 # negative ts
    assert errs([{**ok, "ts": "soon"}])               # non-numeric ts
    assert errs([{"ph": "X", "pid": 1, "tid": 1, "name": "x",
                  "ts": 0, "dur": -5}])               # negative dur
    assert errs([{"ph": "E", "pid": 1, "tid": 1, "name": "x", "ts": 1}])
    assert errs([{"ph": "B", "pid": 1, "tid": 1, "name": "a", "ts": 1},
                 {"ph": "E", "pid": 1, "tid": 1, "name": "b", "ts": 2}])
    assert errs([{"ph": "B", "pid": 1, "tid": 1, "name": "a", "ts": 1}])
    assert errs([{"ph": "B", "pid": 1, "tid": 1, "name": "a", "ts": 5},
                 {"ph": "E", "pid": 1, "tid": 1, "name": "a", "ts": 1}])
    assert obs_trace.validate_chrome_trace("nope")
    assert obs_trace.validate_chrome_trace({"events": []})
    # B/E discipline is per-(pid, tid): interleaved tracks are fine
    assert errs([{"ph": "B", "pid": 1, "tid": 1, "name": "a", "ts": 1},
                 {"ph": "B", "pid": 1, "tid": 2, "name": "b", "ts": 2},
                 {"ph": "E", "pid": 1, "tid": 1, "name": "a", "ts": 3},
                 {"ph": "E", "pid": 1, "tid": 2, "name": "b", "ts": 4}]) == []


def test_tracing_disabled_is_noop(obs_store):
    assert not obs_trace.enabled() and obs_trace.current() is None
    with obs_trace.span("x", track="t") as s:
        assert s is None                      # shared null context
    obs_trace.instant("x")
    obs_trace.complete_wall("x", 0.0, 1.0)
    obs_trace.complete_sim("x", 0.0, 1.0)
    obs_trace.flow("s", 1, 0.0, track="t")
    # a full (untraced) flush stays correct and emits no events anywhere
    server = js.HailServer(obs_store, js.ServerConfig(max_batch=4,
                                                      cluster=CLUSTER))
    for qq in EXPLAIN_QUERIES:
        server.submit(qq)
    server.flush()
    assert all(t.status == "done" for t in server.tickets)


# ---------------------------------------------------------------------------
# acceptance: Ticket.explain() accounts >= 95% of modeled latency
# ---------------------------------------------------------------------------


def _assert_accounts(rec):
    assert rec.accounted_fraction >= 0.95
    if rec.completion_s > 0:      # exact decomposition, not just >= 95%
        assert abs(rec.accounted_s - rec.completion_s) \
            <= 1e-9 + 1e-6 * rec.completion_s


def test_explain_cold_and_result_hit(obs_store):
    server = js.HailServer(obs_store, js.ServerConfig(max_batch=4,
                                                      cluster=CLUSTER))
    for qq in EXPLAIN_QUERIES:
        server.submit(qq, tenant="alice")
    fl = server.flush()
    n = len(EXPLAIN_QUERIES)
    for t in server.tickets[:n]:
        _assert_accounts(t.explain())

    rec = server.tickets[0].explain()        # (0, 1<<30): live on all splits
    assert rec.status == "done" and rec.outcome == "cold"
    assert rec.trigger == "manual"
    assert rec.completion_s > 0 and rec.splits
    assert rec.index_blocks + rec.full_blocks > 0
    assert rec.sched_wait_s + rec.read_s + rec.build_s + rec.rekey_s \
        == pytest.approx(rec.completion_s)
    # agrees with an independent bridge of the same FlushStats
    sched = run_schedule(js.flush_tasks(fl),
                         SimulatedCluster(CLUSTER.n_nodes, CLUSTER.map_slots),
                         spec_factor=None)
    assert rec.completion_s == pytest.approx(
        sched.query_completion_s[rec.ticket_id])
    assert rec.done_wall_s is not None and rec.done_wall_s >= 0
    assert "sched wait" in rec.render() and str(rec) == rec.render()

    # warm repeat: the result tier answers, explain says so
    for qq in EXPLAIN_QUERIES:
        server.submit(qq, tenant="alice")
    server.flush()
    hit = server.tickets[n].explain()
    assert hit.outcome == "result_hit"
    assert hit.completion_s == 0.0 and hit.accounted_fraction >= 0.95
    assert hit.flush["result_cache_hits"] == n


def test_explain_quarantine_survivor(obs_store):
    FaultInjector(obs_store, seed=1).corrupt_chunk(0, 2, "visitDate")
    server = js.HailServer(obs_store, js.ServerConfig(max_batch=2,
                                                      cluster=CLUSTER,
                                                      result_cache=False))
    server.submit(EXPLAIN_QUERIES[0])         # live on the corrupt block
    fl = server.flush()
    assert fl.blocks_quarantined == 1 and fl.corrupt_retries >= 1
    tk = server.tickets[0]
    assert tk.status == "done"
    rec = tk.explain()
    _assert_accounts(rec)
    assert rec.quarantined == 1 and rec.retries_survived >= 1
    assert rec.outcome != "failed" and rec.completion_s > 0
    assert "survived" in rec.render()


def test_explain_frontend_latency_decomposition(obs_store):
    server = js.HailServer(obs_store, js.ServerConfig(max_batch=2,
                                                      cluster=CLUSTER))
    fe = js.ServerFrontend(server, js.FlushPolicy(window_s=0.5))
    for k, qq in enumerate(EXPLAIN_QUERIES):
        fe.offer(qq, tenant=f"t{k % 2}", at=k * 0.25)
    fe.drain()
    assert len(fe.latencies) == len(EXPLAIN_QUERIES)
    for t in server.tickets:
        rec = t.explain()
        _assert_accounts(rec)
        assert rec.trigger in ("batch_full", "window", "drain")
        # frontend latency == queue wait + modeled service, exactly
        assert rec.latency_s == pytest.approx(fe.latencies[t.ticket_id])
        assert rec.latency_s == pytest.approx(rec.queue_wait_s
                                              + rec.completion_s)


def test_explain_before_flush_raises(obs_store):
    server = js.HailServer(obs_store, js.ServerConfig(cluster=CLUSTER))
    tk = server.submit(EXPLAIN_QUERIES[0])
    with pytest.raises(RuntimeError, match="not been flushed"):
        tk.explain()
