"""Logical-axis resolver: rule priorities, divisibility fallbacks, compound
axes, per-tensor uniqueness — against fake production-shaped meshes."""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (DEFAULT_RULES, RULE_PRESETS, TensorSpec,
                                 param_bytes, param_count, resolve_pspec,
                                 scan_device_count, scan_mesh_axes, tspec)


@dataclasses.dataclass
class FakeMesh:
    axis_names: tuple
    devices: np.ndarray


SINGLE = FakeMesh(("data", "model"), np.zeros((16, 16)))
MULTI = FakeMesh(("pod", "data", "model"), np.zeros((2, 16, 16)))
POD1 = FakeMesh(("pod", "data", "model"), np.zeros((1, 16, 16)))
HOST = FakeMesh(("data", "model"), np.zeros((1, 1)))


def test_batch_uses_pod_and_data_on_multipod():
    ps = resolve_pspec((256, 4096), ("batch", "seq"), MULTI)
    assert ps == P(("pod", "data"))
    ps = resolve_pspec((256, 4096), ("batch", "seq"), SINGLE)
    assert ps == P("data")


def test_compound_prefix_fallback():
    # batch=2 divides 'pod' (2) but not pod*data (32) -> prefix ('pod',)
    ps = resolve_pspec((2, 128), ("batch", "seq"), MULTI)
    assert ps == P("pod")


def test_divisibility_drops_axis():
    # kv_heads=8 does not divide model=16 -> replicated
    ps = resolve_pspec((1024, 8, 128), ("embed", "kv_heads", "head_dim"), SINGLE)
    assert ps == P("data")


def test_kv_seq_falls_to_model_when_data_taken():
    # cache (B, S, KV, D): batch takes data, kv_seq falls to model
    ps = resolve_pspec((128, 32768, 8, 256),
                       ("batch", "kv_seq", "act_kv_heads", "head_dim"), SINGLE)
    assert ps == P("data", "model")


def test_batch_one_long_context():
    # batch=1 unshardable; kv_seq gets data
    ps = resolve_pspec((1, 524288, 8, 256),
                       ("batch", "kv_seq", "act_kv_heads", "head_dim"), SINGLE)
    assert ps == P(None, "data")


def test_axis_used_once_per_tensor():
    # vocab and embed both want axes; embed->data, vocab->model, no reuse
    ps = resolve_pspec((262144, 2560), ("vocab", "embed"), SINGLE)
    assert ps == P("model", "data")


def test_expert_sharding():
    # experts take 'model'; the FFN dim then finds it used and replicates
    ps = resolve_pspec((128, 7168, 4864),
                       ("expert", "embed", "expert_mlp"), SINGLE)
    assert ps == P("model", "data")
    # 8 experts don't divide 16 -> the FFN dim falls back to 'model'
    # (the confirmed §Perf fix for mixtral: no replicated expert compute)
    ps = resolve_pspec((8, 6144, 16384),
                       ("expert", "embed", "expert_mlp"), SINGLE)
    assert ps == P(None, "data", "model")


def test_param_accounting():
    spec = {"a": tspec((4, 8), ("embed", "mlp")),
            "b": tspec((8,), ("act_embed",), jnp.bfloat16)}
    assert param_count(spec) == 40
    assert param_bytes(spec) == 4 * 8 * 4 + 8 * 2


def test_presets_differ_from_baseline_on_production_mesh():
    # every non-baseline preset must CHANGE at least one resolution on the
    # production mesh, else the --rules flag is a silent no-op (the fsdp
    # preset's embed entry used to be byte-identical to DEFAULT_RULES)
    witnesses = [((4096, 4096), ("embed", "mlp")),
                 ((262144, 2560), ("vocab", "embed")),
                 ((256, 4096), ("batch", "seq"))]
    for name, rules in RULE_PRESETS.items():
        if name == "baseline":
            continue
        assert any(
            resolve_pspec(shape, axes, SINGLE, rules)
            != resolve_pspec(shape, axes, SINGLE) for shape, axes in
            witnesses), f"preset {name!r} is a no-op on the production mesh"


def test_fsdp_embed_shards_compound():
    # fsdp fully shards the weight embed dim over the (data, model) grid
    ps = resolve_pspec((4096, 4096), ("embed", "mlp"), SINGLE,
                       RULE_PRESETS["fsdp"])
    assert ps == P(("data", "model"))
    assert resolve_pspec((4096, 4096), ("embed", "mlp"), SINGLE) \
        == P("data", "model")


def test_size1_axis_dropped_from_compound():
    # pod=1 shards nothing: ("pod", "data") canonicalises to plain "data",
    # and the unused 'pod' must NOT be burned for later logical axes
    ps = resolve_pspec((256, 4096), ("batch", "seq"), POD1)
    assert ps == P("data")
    # batch=2 divides pod(=2) on MULTI but nothing on POD1 -> replicated,
    # never a non-canonical (("pod",),) entry
    assert resolve_pspec((2, 128), ("batch", "seq"), POD1) == P()


def test_size1_axis_dropped_single_candidate():
    # on a (1, 1) host mesh every candidate shards nothing -> replicated
    assert resolve_pspec((256, 4096), ("batch", "seq"), HOST) == P()
    assert resolve_pspec((262144, 2560), ("vocab", "embed"), HOST) == P()


def test_scan_mesh_axes():
    assert scan_mesh_axes(MULTI) == ("pod", "data")
    assert scan_mesh_axes(POD1) == ("data",)
    assert scan_mesh_axes(SINGLE) == ("data",)
    assert scan_mesh_axes(HOST) == ()       # callers fall back to serial
    assert scan_device_count(MULTI, ("pod", "data")) == 32
    assert scan_device_count(HOST, ()) == 1


def test_spmd_aggregate_bucket_mismatch_is_typed():
    from repro.core import mapreduce as mr

    @dataclasses.dataclass
    class FourDev:               # the check fires before shard_map is built
        shape: dict

    k = jnp.zeros((2, 8), jnp.int32)
    v = jnp.zeros((2, 8), jnp.float32)
    m = jnp.ones((2, 8), bool)
    with pytest.raises(ValueError, match=r"n_buckets=7.*'data' size 4"):
        mr.spmd_aggregate(FourDev({"data": 4}), k, v, m, n_buckets=7,
                          axis="data")


def test_assign_nodes_overreplication_is_typed():
    from repro.core.store import assign_nodes
    with pytest.raises(ValueError, match=r"replication=4.*n_nodes=3"):
        assign_nodes(8, replication=4, n_nodes=3)
    assert assign_nodes(8, replication=3, n_nodes=3).shape == (3, 8)
