"""Logical-axis resolver: rule priorities, divisibility fallbacks, compound
axes, per-tensor uniqueness — against fake production-shaped meshes."""
import dataclasses

import numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (DEFAULT_RULES, TensorSpec, param_bytes,
                                 param_count, resolve_pspec, tspec)


@dataclasses.dataclass
class FakeMesh:
    axis_names: tuple
    devices: np.ndarray


SINGLE = FakeMesh(("data", "model"), np.zeros((16, 16)))
MULTI = FakeMesh(("pod", "data", "model"), np.zeros((2, 16, 16)))


def test_batch_uses_pod_and_data_on_multipod():
    ps = resolve_pspec((256, 4096), ("batch", "seq"), MULTI)
    assert ps == P(("pod", "data"))
    ps = resolve_pspec((256, 4096), ("batch", "seq"), SINGLE)
    assert ps == P("data")


def test_compound_prefix_fallback():
    # batch=2 divides 'pod' (2) but not pod*data (32) -> prefix ('pod',)
    ps = resolve_pspec((2, 128), ("batch", "seq"), MULTI)
    assert ps == P("pod")


def test_divisibility_drops_axis():
    # kv_heads=8 does not divide model=16 -> replicated
    ps = resolve_pspec((1024, 8, 128), ("embed", "kv_heads", "head_dim"), SINGLE)
    assert ps == P("data")


def test_kv_seq_falls_to_model_when_data_taken():
    # cache (B, S, KV, D): batch takes data, kv_seq falls to model
    ps = resolve_pspec((128, 32768, 8, 256),
                       ("batch", "kv_seq", "act_kv_heads", "head_dim"), SINGLE)
    assert ps == P("data", "model")


def test_batch_one_long_context():
    # batch=1 unshardable; kv_seq gets data
    ps = resolve_pspec((1, 524288, 8, 256),
                       ("batch", "kv_seq", "act_kv_heads", "head_dim"), SINGLE)
    assert ps == P(None, "data")


def test_axis_used_once_per_tensor():
    # vocab and embed both want axes; embed->data, vocab->model, no reuse
    ps = resolve_pspec((262144, 2560), ("vocab", "embed"), SINGLE)
    assert ps == P("model", "data")


def test_expert_sharding():
    # experts take 'model'; the FFN dim then finds it used and replicates
    ps = resolve_pspec((128, 7168, 4864),
                       ("expert", "embed", "expert_mlp"), SINGLE)
    assert ps == P("model", "data")
    # 8 experts don't divide 16 -> the FFN dim falls back to 'model'
    # (the confirmed §Perf fix for mixtral: no replicated expert compute)
    ps = resolve_pspec((8, 6144, 16384),
                       ("expert", "embed", "expert_mlp"), SINGLE)
    assert ps == P(None, "data", "model")


def test_param_accounting():
    spec = {"a": tspec((4, 8), ("embed", "mlp")),
            "b": tspec((8,), ("act_embed",), jnp.bfloat16)}
    assert param_count(spec) == 40
    assert param_bytes(spec) == 4 * 8 * 4 + 8 * 2
