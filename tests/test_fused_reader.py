"""Fused split reader: one-dispatch-per-split, zero per-query recompiles,
kernel/oracle equivalence on MIXED-REPLICA and FAILOVER splits, and the
Hadoop++ upload phase accounting."""
import numpy as np
import pytest

from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.kernels import ops

Q1 = q.HailQuery(filter=("visitDate", 7305, 7670), projection=("sourceIP",))


def _equiv(store, query, qp, ids=None):
    a = q.read_hail(store, query, qp, ids)
    b = q.read_hail_kernels(store, query, qp, ids)
    am, bm = np.asarray(a.mask), np.asarray(b.mask)
    np.testing.assert_array_equal(am, bm)
    for c in query.projection:
        np.testing.assert_array_equal(np.asarray(a.cols[c])[am],
                                      np.asarray(b.cols[c])[bm])
    np.testing.assert_allclose(np.asarray(a.rows_read_frac),
                               np.asarray(b.rows_read_frac))


def test_one_dispatch_per_split(hail_store):
    qp = q.plan(hail_store, Q1)
    with ops.stats_scope() as s:
        q.read_hail_kernels(hail_store, Q1, qp)                # all blocks
        assert s.dispatches["hail_read"] == 1
        q.read_hail_kernels(hail_store, Q1, qp, [0, 2])        # 2-block split
    assert s.dispatches["hail_read"] == 2
    # no stray per-block kernel launches
    assert s.dispatches["pax_scan"] == 0
    assert s.dispatches["index_search"] == 0


def test_zero_recompiles_across_query_ranges(hail_store):
    qp = q.plan(hail_store, Q1)
    ranges = [(7305, 7670), (0, 100), (1, 2), (5000, 20000), (7, 7),
              (123, 9999), (0, 2**30), (42, 4242), (1000, 1001), (8, 800)]
    with ops.stats_scope() as s:
        for lo, hi in ranges:
            query = q.HailQuery(filter=("visitDate", lo, hi),
                                projection=("sourceIP",))
            q.read_hail_kernels(hail_store, query, qp)
    assert s.dispatches["hail_read"] == len(ranges)
    # at most the first call traces (0 when another test already warmed the
    # same store shape): ZERO recompiles after the first, across all ranges
    assert s.traces["hail_read"] <= 1


def test_mixed_replica_split_equivalence(hail_store):
    """One split whose blocks read from DIFFERENT replicas (index + full
    scan mixed) must still be a single fused dispatch and match the oracle."""
    qp = q.plan(hail_store, Q1)
    other = hail_store.replica_by_key("sourceIP")
    qp.replica_for_block[1::2] = other          # half the blocks fail over
    qp.index_scan[1::2] = False                 # ...to a non-matching index
    assert len(np.unique(qp.replica_for_block)) == 2
    with ops.stats_scope() as s:
        _equiv(hail_store, Q1, qp)
    assert s.dispatches["hail_read"] == 1       # one fused dispatch


def test_failover_split_equivalence(hail_store, oracle_rows):
    """After a node failure the re-planned blocks full-scan another replica;
    the fused reader must agree with the jnp reader on the new plan."""
    cols, bad = oracle_rows
    nn = hail_store.namenode
    victim = int(hail_store.replicas[
        hail_store.replica_by_key("visitDate")].nodes[0])
    nn.kill_node(victim)
    try:
        qp = q.plan(hail_store, Q1)
        assert not qp.index_scan.all()
        _equiv(hail_store, Q1, qp)
        res = q.collect(q.read_hail_kernels(hail_store, Q1, qp))
        m = (cols["visitDate"] >= 7305) & (cols["visitDate"] <= 7670) & ~bad
        np.testing.assert_array_equal(np.sort(res["sourceIP"]),
                                      np.sort(cols["sourceIP"][m]))
    finally:
        nn.revive()


def test_run_job_kernel_reader_with_failover(hail_store):
    """run_job(reader='kernels') routes every split — including the
    per-block retry splits re-planned after a node failure — through the
    fused reader, and results match the jnp reader job."""
    base = mr.run_job(hail_store, Q1, splitting="hail")
    with ops.stats_scope() as s:
        failed = mr.run_job(hail_store, Q1, splitting="hail",
                            fail_node_at=0.5, reader="kernels")
    assert failed.results["n_rows"] == base.results["n_rows"]
    assert failed.rescheduled_tasks > 0
    # exactly one fused dispatch per executed split, none per block
    assert s.dispatches["hail_read"] == failed.n_tasks
    assert s.dispatches["pax_scan"] == 0


def test_failover_mid_convergence_still_offers_indexing(uservisits_raw):
    """Kill a node mid-convergence: the re-queued splits of the dead node
    fall back to full scan on a surviving replica AND are still offered for
    adaptive indexing, so convergence survives the failure."""
    _, raw = uservisits_raw
    store, _ = up.hail_upload(sc.USERVISITS, raw, index_columns=(),
                              partition_size=128, n_nodes=6)
    cfg = mr.AdaptiveConfig(offer_rate=0.5)
    base = mr.run_job(store, Q1, adaptive=cfg)       # partial convergence
    frac0 = store.indexed_fraction("visitDate")
    assert 0.0 < frac0 < 1.0
    with ops.stats_scope() as s:
        failed = mr.run_job(store, Q1, adaptive=cfg, fail_node_at=0.5,
                            reader="kernels")
    assert failed.results["n_rows"] == base.results["n_rows"]
    assert failed.rescheduled_tasks > 0
    # every executed split (retries included) = one fused dispatch
    assert s.dispatches["hail_read"] == failed.n_tasks
    # unconverged blocks full-scanned...
    assert s.dispatches["full_scan_blocks"] > 0
    # ...and the job still built indexes while handling the failure
    assert failed.blocks_indexed > 0
    assert store.indexed_fraction("visitDate") > frac0
    # the store keeps converging to zero full-scan work after the failure
    while store.indexed_fraction("visitDate") < 1.0:
        mr.run_job(store, Q1, adaptive=cfg)
    with ops.stats_scope() as s2:
        final = mr.run_job(store, Q1, adaptive=cfg, reader="kernels")
    assert s2.dispatches["full_scan_blocks"] == 0
    assert final.results["n_rows"] == base.results["n_rows"]


def test_failover_races_demotion_kernel_reader(uservisits_raw):
    """Chaos: node loss racing a governor demotion in ONE kernels-reader
    job.  The re-queued splits must full-scan the just-demoted replica
    through the fused reader (one dispatch per split, no stray launches),
    still be offered rebuilds, and the shifted workload must reconverge."""
    from repro.core import governor as gv

    _, raw = uservisits_raw
    store, _ = up.hail_upload(sc.USERVISITS, raw, index_columns=(),
                              partition_size=128, n_nodes=6)
    n_blocks = store.n_blocks
    gv.govern(store, max_indexed_blocks=n_blocks)
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    base = mr.run_job(store, Q1, adaptive=cfg)       # converge on visitDate
    assert store.indexed_fraction("visitDate") == 1.0
    q2 = q.HailQuery(filter=("sourceIP", 0, 1 << 30),
                     projection=("visitDate",))
    base2 = mr.run_job(store, q2)                    # oracle row count
    with ops.stats_scope() as s:
        failed = mr.run_job(store, q2, adaptive=cfg, fail_node_at=0.5,
                            reader="kernels")
    # the shift evicted visitDate's replica while the failure was handled
    assert failed.blocks_demoted == n_blocks
    assert failed.rescheduled_tasks > 0
    assert failed.results["n_rows"] == base2.results["n_rows"]
    # every executed split (including post-demotion retries that full-scan
    # the demoted replica) = exactly one fused dispatch
    assert s.dispatches["hail_read"] == failed.n_tasks
    assert s.dispatches["pax_scan"] == 0
    assert s.dispatches["full_scan_blocks"] > 0
    assert s.dispatches["full_scan_blocks[sourceIP]"] > 0
    # the job still built indexes for the new workload under the budget
    assert failed.blocks_indexed > 0
    assert store.total_indexed_blocks() <= n_blocks
    while store.indexed_fraction("sourceIP") < 1.0:
        mr.run_job(store, q2, adaptive=cfg)
    with ops.stats_scope() as s2:
        final = mr.run_job(store, q2, adaptive=cfg, reader="kernels")
    assert s2.dispatches["full_scan_blocks"] == 0
    assert final.results["n_rows"] == base2.results["n_rows"]
    # the old workload still answers exactly, now by full scan
    refetch = mr.run_job(store, Q1, reader="kernels")
    assert refetch.results["n_rows"] == base.results["n_rows"]


def test_batch_reader_equals_serial_reads(hail_store):
    """Shared-scan batch reader: ONE fused dispatch serves Q queries with
    per-query masks identical to Q serial single-query reads — including on
    a MIXED split (index-scan and failover full-scan blocks together)."""
    ranges = [(7305, 7670), (0, 100), (5000, 20000), (7, 7), (0, 2**30)]
    queries = [q.HailQuery(filter=("visitDate", lo, hi),
                           projection=("sourceIP",)) for lo, hi in ranges]
    qp = q.plan(hail_store, Q1)
    other = hail_store.replica_by_key("sourceIP")
    qp.replica_for_block[1::2] = other          # half the blocks fail over
    qp.index_scan[1::2] = False
    with ops.stats_scope() as s:
        batch, shared = q.read_hail_batch(hail_store, queries, qp)
    assert s.dispatches["hail_read"] == 1       # one (split, batch) dispatch
    assert s.dispatches["hail_read_batch"] == 1
    for qq, res in zip(queries, batch):
        single = q.read_hail_kernels(hail_store, qq, qp)
        am, bm = np.asarray(single.mask), np.asarray(res.mask)
        np.testing.assert_array_equal(am, bm)
        for c in qq.projection:
            np.testing.assert_array_equal(np.asarray(single.cols[c])[am],
                                          np.asarray(res.cols[c])[bm])
        np.testing.assert_allclose(np.asarray(single.rows_read_frac),
                                   np.asarray(res.rows_read_frac))
    # physical shared-scan bytes: at most the widest per-block range summed
    fracs = np.stack([np.asarray(r.rows_read_frac) for r in batch])
    assert float(shared) == pytest.approx(
        fracs.max(axis=0).sum() * 4 * hail_store.rows_per_block * 2)


def test_run_job_pipelines_splits(hail_store):
    st = mr.run_job(hail_store, Q1, splitting="hail")
    assert len(st.split_s) == st.n_tasks
    assert st.results["n_rows"] > 0


# ---------------------------------------------------------------------------
# Hadoop++ upload phase accounting
# ---------------------------------------------------------------------------


def test_hadooppp_phase_accounting(uservisits_raw):
    _, raw = uservisits_raw
    _, s1 = up.hdfs_upload(sc.USERVISITS, raw, replication=3, n_nodes=6)
    _, spp = up.hadooppp_upload(sc.USERVISITS, raw, "visitDate", n_nodes=6)
    # the trojan job re-reads exactly what phase 1 wrote — and that extra
    # read is charged once, as modeled I/O, not also as compute wall
    assert spp.extra_read_bytes == s1.written_bytes
    assert set(spp.phases) == {"hdfs", "trojan_rewrite"}
    assert spp.wall_s == pytest.approx(sum(spp.phases.values()))
    # modeled cluster time charges the extra read sequentially
    from benchmarks.common import upload_model_seconds
    base = upload_model_seconds(spp)
    no_extra = upload_model_seconds(
        up.UploadStats(wall_s=spp.wall_s, ascii_bytes=spp.ascii_bytes,
                       written_bytes=spp.written_bytes))
    assert base > no_extra
