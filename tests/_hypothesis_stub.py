"""Minimal stand-in for the `hypothesis` package (not installable in this
container).  Provides just the surface the test-suite uses — ``given``,
``settings`` and the ``integers`` / ``sampled_from`` / ``lists`` strategies —
with DETERMINISTIC example generation (seeded per test name), so property
tests still sweep a spread of inputs and failures reproduce.

Installed into ``sys.modules['hypothesis']`` by conftest.py only when the
real package is missing; when hypothesis is available it is used untouched.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    def draw(rng):
        # numpy integers() upper bound is exclusive; hypothesis' inclusive
        return int(rng.integers(min_value, max_value + 1))
    return _Strategy(draw)


def floats(min_value, max_value):
    def draw(rng):
        return float(rng.uniform(min_value, max_value))
    return _Strategy(draw)


def sampled_from(options):
    opts = list(options)

    def draw(rng):
        return opts[int(rng.integers(0, len(opts)))]
    return _Strategy(draw)


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


def booleans():
    def draw(rng):
        return bool(rng.integers(0, 2))
    return _Strategy(draw)


def tuples(*strategies):
    def draw(rng):
        return tuple(s.example(rng) for s in strategies)
    return _Strategy(draw)


def just(value):
    return _Strategy(lambda rng: value)


class settings:  # noqa: N801 - mirrors hypothesis' API
    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn.__stub_max_examples__ = self.max_examples
        return fn


def given(*strategies):
    """Append drawn values after any pytest-fixture args, like hypothesis.

    The wrapper's signature drops the strategy-bound (trailing) parameters so
    pytest only injects fixtures for the remaining names.
    """
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        fixture_params = params[:len(params) - len(strategies)]
        drawn_names = [p.name for p in params[len(params) - len(strategies):]]

        @functools.wraps(fn)
        def wrapper(*fixture_args, **fixture_kw):
            # Read from the wrapper: functools.wraps copied the attribute
            # here when @settings sat below @given, and @settings sets it
            # here directly when it sits above (the conventional order).
            n = getattr(wrapper, "__stub_max_examples__",
                        DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rng = np.random.default_rng((seed, i))
                drawn = [s.example(rng) for s in strategies]
                fn(*fixture_args, **fixture_kw,
                   **dict(zip(drawn_names, drawn)))

        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        return wrapper
    return deco


class strategies:  # noqa: N801 - `from hypothesis import strategies as st`
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    booleans = staticmethod(booleans)
    tuples = staticmethod(tuples)
    just = staticmethod(just)
