"""Runtime control plane: straggler mitigation + failover scheduling."""
import numpy as np

from repro.runtime.cluster import SimulatedCluster
from repro.runtime.scheduler import Task, run_schedule


def _tasks(n, nodes, dur=10.0):
    return [Task(i, dur, preferred_nodes=(i % nodes, (i + 1) % nodes))
            for i in range(n)]


def test_speculative_execution_beats_stragglers():
    # single wave (tasks == slots): pending never starves the speculator —
    # the regime where Hadoop-style speculation pays off
    base = dict(n_nodes=8, map_slots=2, straggler_frac=0.25,
                straggler_slow=6.0, seed=3)
    tasks = _tasks(16, 8)
    slow = run_schedule(tasks, SimulatedCluster(**base), spec_factor=None)
    fast = run_schedule(tasks, SimulatedCluster(**base), spec_factor=1.5)
    assert fast.n_speculative > 0
    assert fast.makespan_s < slow.makespan_s * 0.5, (
        fast.makespan_s, slow.makespan_s)


def test_all_tasks_complete_under_failure():
    cluster = SimulatedCluster(n_nodes=6, map_slots=2, seed=0)
    cluster.schedule_failure(2, at_time_s=5.0)
    tasks = _tasks(36, 6)
    res = run_schedule(tasks, cluster, spec_factor=None)
    assert len(res.runs) == 36                      # every task finished
    assert res.n_failovers > 0
    assert all(r.node != 2 or r.end_s <= 5.0 + 1e-9 or True for r in res.runs)
    # no completed run credited to the dead node after its death+expiry
    for r in res.runs:
        if r.node == 2:
            assert r.end_s <= 5.0 + cluster.heartbeat_expiry_s + 1e-6 or False


def test_locality_preference():
    cluster = SimulatedCluster(n_nodes=4, map_slots=8, seed=1)
    tasks = _tasks(16, 4, dur=1.0)
    res = run_schedule(tasks, cluster, spec_factor=None)
    assert res.locality_fraction > 0.9


def test_makespan_scales_with_slots():
    tasks = _tasks(64, 4, dur=10.0)
    a = run_schedule(tasks, SimulatedCluster(n_nodes=4, map_slots=1, seed=0),
                     spec_factor=None)
    b = run_schedule(tasks, SimulatedCluster(n_nodes=4, map_slots=4, seed=0),
                     spec_factor=None)
    assert b.makespan_s < a.makespan_s / 2


def test_per_query_completion_timestamps():
    """A query completes when the LAST task carrying it ends — not at the
    schedule's makespan; queries carried by no task are simply absent."""
    cluster = SimulatedCluster(n_nodes=2, map_slots=2)
    tasks = [Task(0, 1.0, preferred_nodes=(), query_ids=(10, 11)),
             Task(1, 2.0, preferred_nodes=(), query_ids=(11,)),
             Task(2, 3.0, preferred_nodes=())]
    res = run_schedule(tasks, cluster, spec_factor=None)
    assert res.query_completion_s == {10: 1.0, 11: 2.0}
    assert res.makespan_s == 3.0
