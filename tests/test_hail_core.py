"""HAIL core behaviour: parsing, checksums, indexes, the scan-equivalence
invariant, replica failover, namenode metadata, splitting, MR jobs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import checksum as ck
from repro.core import index as idx
from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import splitting as sp
from repro.core import upload as up
from repro.core.parse import format_rows, parse_block
from repro.core.schema import ROWID

from conftest import BLOCKS, PART, ROWS


# ---------------------------------------------------------------------------
# Parser
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=4, max_size=4),
       st.lists(st.integers(0, 99999), min_size=4, max_size=4))
def test_parser_roundtrip(a, b):
    schema = sc.Schema("t", (sc.Column("x"), sc.Column("y", ascii_width=5)))
    cols = {"x": np.array(a, np.int64), "y": np.array(b, np.int64)}
    raw = format_rows(schema, cols)
    got, bad = parse_block(schema, jnp.asarray(raw))
    assert not bool(bad.any())
    np.testing.assert_array_equal(np.asarray(got["x"]), np.array(a, np.int32))
    np.testing.assert_array_equal(np.asarray(got["y"]), np.array(b, np.int32))


def test_parser_flags_bad_records():
    schema = sc.Schema("t", (sc.Column("x", ascii_width=4),))
    raw = format_rows(schema, {"x": np.arange(8)})
    raw[3, 1] = ord("z")
    _, bad = parse_block(schema, jnp.asarray(raw))
    assert np.asarray(bad).tolist() == [False, False, False, True,
                                        False, False, False, False]


# ---------------------------------------------------------------------------
# Checksums
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 511))
def test_checksum_detects_mutation(seed, pos):
    r = np.random.default_rng(seed)
    data = jnp.asarray(r.integers(0, 255, 2048, dtype=np.int32))
    sums = ck.chunk_checksums(data)
    corrupted = data.at[pos].add(1)
    assert not bool(ck.verify(corrupted, sums).all())
    assert bool(ck.verify(data, sums).all())


def test_checksum_detects_permutation():
    data = jnp.arange(512, dtype=jnp.int32)
    sums = ck.chunk_checksums(data)
    assert not bool(ck.verify(data[::-1], sums).all())


def test_per_replica_checksums_differ(hail_store):
    a = hail_store.replicas[0].checksums["sourceIP"]
    b = hail_store.replicas[1].checksums["sourceIP"]
    assert not bool((a == b).all())   # different sort orders -> different sums


# ---------------------------------------------------------------------------
# Clustered index
# ---------------------------------------------------------------------------


def test_partition_mins_sorted(hail_store):
    for rep in hail_store.replicas:
        mins = np.asarray(rep.mins)
        assert (np.diff(mins, axis=1) >= 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**20), st.integers(0, 2**20), st.integers(0, 2**31 - 1))
def test_index_scan_equals_full_scan(lo, hi, seed):
    lo, hi = min(lo, hi), max(lo, hi)
    r = np.random.default_rng(seed)
    keys = jnp.asarray(np.sort(r.integers(0, 2**20, 1024).astype(np.int32)))
    mins = idx.build_root(keys, 128)
    got = idx.index_scan_mask(keys, mins, lo, hi, 128)
    want = idx.full_scan_mask(keys, lo, hi)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rows_read_fraction_selective():
    keys = jnp.arange(1024, dtype=jnp.int32)
    mins = idx.build_root(keys, 128)
    frac = idx.rows_read_fraction(mins, 0, 10, 128, 1024)
    assert float(frac) == pytest.approx(128 / 1024)


# ---------------------------------------------------------------------------
# The system invariant: HAIL index scan == HAIL full scan == Hadoop scan
# ---------------------------------------------------------------------------

Q1 = q.HailQuery(filter=("visitDate", 7305, 7670), projection=("sourceIP",))


def _sorted_result(res):
    rows = q.collect(res)
    order = np.argsort(rows[ROWID])
    return {k: v[order] for k, v in rows.items()}


def test_scan_equivalence(hail_store, hdfs_store, oracle_rows):
    cols, bad = oracle_rows
    m = (cols["visitDate"] >= 7305) & (cols["visitDate"] <= 7670) & ~bad
    qp = q.plan(hail_store, Q1)
    assert qp.index_scan.all()
    hail = _sorted_result(q.read_hail(hail_store, Q1, qp))
    hadoop = _sorted_result(q.read_hadoop(hdfs_store, Q1))
    np.testing.assert_array_equal(hail["sourceIP"], cols["sourceIP"][m])
    np.testing.assert_array_equal(hadoop["sourceIP"], cols["sourceIP"][m])
    np.testing.assert_array_equal(hail[ROWID], hadoop[ROWID])


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(["sourceIP", "visitDate", "adRevenue", "duration"]),
       st.integers(0, 2**30), st.integers(0, 2**30))
def test_query_equivalence_property(hail_store, hdfs_store, oracle_rows,
                                    col, lo, hi):
    """For any filter column (indexed or not) and any range, HAIL == Hadoop."""
    lo, hi = min(lo, hi), max(lo, hi)
    cols, bad = oracle_rows
    query = q.HailQuery(filter=(col, lo, hi), projection=("duration",))
    qp = q.plan(hail_store, query)
    hail = _sorted_result(q.read_hail(hail_store, query, qp))
    m = (cols[col] >= lo) & (cols[col] <= hi) & ~bad
    np.testing.assert_array_equal(hail["duration"], cols["duration"][m])


def test_replica_equivalence(hail_store, oracle_rows):
    """Any replica reconstructs the same logical rows (failover invariant)."""
    cols, bad = oracle_rows
    query = q.HailQuery(filter=("duration", 100, 5000), projection=("destURL",))
    results = []
    for rid in range(hail_store.replication):
        qp = q.plan(hail_store, query)
        qp.replica_for_block[:] = rid
        qp.index_scan[:] = hail_store.replicas[rid].sort_key == "duration"
        results.append(_sorted_result(q.read_hail(hail_store, query, qp)))
    for r2 in results[1:]:
        np.testing.assert_array_equal(results[0][ROWID], r2[ROWID])
        np.testing.assert_array_equal(results[0]["destURL"], r2["destURL"])


def test_bad_records_excluded_and_counted(hail_store, oracle_rows):
    _, bad = oracle_rows
    assert int(hail_store.bad_counts.sum()) == int(bad.sum()) > 0


# ---------------------------------------------------------------------------
# Namenode / planning / failover
# ---------------------------------------------------------------------------


def test_namenode_metadata(hail_store):
    nn = hail_store.namenode
    assert len(nn.dir_block) == BLOCKS
    infos = nn.replicas(0)
    assert {i.sort_key for i in infos} == {"visitDate", "sourceIP", "adRevenue"}
    hosts = nn.get_hosts_with_index(0, "sourceIP")
    assert len(hosts) == 1


def test_plan_prefers_matching_index(hail_store):
    qp = q.plan(hail_store, q.HailQuery(filter=("sourceIP", 0, 100),
                                        projection=("duration",)))
    want = hail_store.replica_by_key("sourceIP")
    assert (qp.replica_for_block == want).all()
    assert qp.index_scan.all()


def test_failover_falls_back_to_scan(hail_store, oracle_rows):
    cols, bad = oracle_rows
    nn = hail_store.namenode
    victim = int(hail_store.replicas[
        hail_store.replica_by_key("visitDate")].nodes[0])
    nn.kill_node(victim)
    try:
        qp = q.plan(hail_store, Q1)
        assert not qp.index_scan.all()          # some blocks lost their index
        res = _sorted_result(q.read_hail(hail_store, Q1, qp))
        m = (cols["visitDate"] >= 7305) & (cols["visitDate"] <= 7670) & ~bad
        np.testing.assert_array_equal(res["sourceIP"], cols["sourceIP"][m])
    finally:
        nn.revive()


def test_all_replicas_lost_raises(hail_store):
    nn = hail_store.namenode
    for node in range(6):
        nn.kill_node(node)
    with pytest.raises(RuntimeError):
        q.plan(hail_store, Q1)
    nn.revive()


# ---------------------------------------------------------------------------
# Splitting + jobs
# ---------------------------------------------------------------------------


def test_hail_splitting_coalesces(hail_store):
    qp = q.plan(hail_store, Q1)
    hs = sp.hail_splits(hail_store, qp, map_slots=2)
    ds = sp.hadoop_splits(hail_store, qp)
    assert len(hs) <= len(ds)
    assert sorted(b for s in hs for b in s.block_ids) == list(range(BLOCKS))
    for s in hs:   # locality: every block in a split reads from its node
        for b in s.block_ids:
            assert qp.nodes[b] == s.node


def test_job_results_match_across_policies(hail_store, hdfs_store):
    r1 = mr.run_job(hail_store, Q1, splitting="hail")
    r2 = mr.run_job(hail_store, Q1, splitting="hadoop")
    r3 = mr.run_job(hdfs_store, Q1)
    assert r1.results["n_rows"] == r2.results["n_rows"] == r3.results["n_rows"]
    assert r1.n_tasks <= r2.n_tasks


def test_job_failover_preserves_results(hail_store):
    base = mr.run_job(hail_store, Q1, splitting="hail")
    failed = mr.run_job(hail_store, Q1, splitting="hail", fail_node_at=0.5)
    assert failed.results["n_rows"] == base.results["n_rows"]


def test_spmd_groupby_oracle(hail_store, oracle_rows):
    from repro.launch.mesh import make_mesh
    cols, bad = oracle_rows
    mesh = make_mesh((1,), ("data",))
    qp = q.plan(hail_store, Q1)
    res = q.read_hail(hail_store, Q1, qp)
    rep = hail_store.replicas[int(qp.replica_for_block[0])]
    sums, cnts = mr.spmd_aggregate(mesh, rep.cols["countryCode"],
                                   rep.cols["adRevenue"], res.mask,
                                   n_buckets=256)
    m = (cols["visitDate"] >= 7305) & (cols["visitDate"] <= 7670) & ~bad
    want = np.zeros(256)
    np.add.at(want, cols["countryCode"][m] % 256, cols["adRevenue"][m])
    np.testing.assert_allclose(np.asarray(sums), want, rtol=1e-6)
