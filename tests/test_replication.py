"""Dynamic replication: add_replica / decommission_replica store transitions
and the heat-driven ReplicationController closing the loop at job boundaries
(replacing the static factor-of-3 replication)."""
import numpy as np
import pytest

from repro.core import governor as gvn
from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows
from repro.core.schema import ROWID
from repro.obs.metrics import MetricsRegistry

ROWS = 256
BLOCKS = 4
PART = 64


@pytest.fixture()
def two_rep_store():
    """Fresh per-test store with TWO claimed replicas (visitDate, sourceIP)
    on a 6-node cluster — adRevenue has no index slot until one is added."""
    cols = sc.gen_uservisits(ROWS * BLOCKS, seed=11)
    raw = format_rows(sc.USERVISITS, cols, bad_fraction=0.002)
    store, _ = up.hail_upload(
        sc.USERVISITS, raw.reshape(BLOCKS, ROWS, -1),
        ["visitDate", "sourceIP"], partition_size=PART, n_nodes=6)
    return store, cols


Q_AD = q.HailQuery(filter=("adRevenue", 100, 5000),
                   projection=("sourceIP",))
Q_VD = q.HailQuery(filter=("visitDate", 7305, 7670),
                   projection=("sourceIP",))
Q_SIP = q.HailQuery(filter=("sourceIP", 0, 1 << 30),
                    projection=("visitDate",))


def test_add_replica_unclaimed_and_placed(two_rep_store):
    store, _ = two_rep_store
    v0 = store.version
    base = mr.run_job(store, Q_AD).results["n_rows"]
    rid = store.add_replica()
    assert rid == 2
    rep = store.replicas[rid]
    # unclaimed (claimable by the next adaptive job for any hot column)
    assert rep.sort_key is None and not rep.indexed.any()
    assert store.adaptive_replica_for("adRevenue") == rid
    # upload order restored from a SORTED donor: rowids ascend per block
    rowid = np.asarray(rep.cols[ROWID])
    assert (np.diff(rowid, axis=1) > 0).all()
    # distinct-nodes invariant holds across all live replicas, per block
    for b in range(store.n_blocks):
        nodes = {int(store.replicas[i].nodes[b])
                 for i in store.live_replica_ids()}
        assert len(nodes) == 3
    # NON-destructive: no version bump, row-sets unchanged
    assert store.version == v0
    assert mr.run_job(store, Q_AD).results["n_rows"] == base


def test_add_replica_converges_adaptively(two_rep_store):
    store, cols = two_rep_store
    rid = store.add_replica()
    adaptive = mr.AdaptiveConfig(offer_rate=1.0)
    mr.run_job(store, Q_AD, adaptive=adaptive)      # claims + builds rid
    assert store.replicas[rid].sort_key == "adRevenue"
    assert store.replicas[rid].indexed.all()
    post = mr.run_job(store, Q_AD, adaptive=adaptive)
    assert post.full_scan_blocks == 0               # index scan now
    want = ((cols["adRevenue"] >= 100) & (cols["adRevenue"] <= 5000))
    # bad rows excluded by the store, so oracle is an upper bound tight to
    # within the injected bad fraction
    assert post.results["n_rows"] <= int(want.sum())


def test_add_replica_exhausts_node_offsets(two_rep_store):
    store, _ = two_rep_store
    store.add_replica(n_nodes=3)                    # offset slot 2 of 3
    with pytest.raises(ValueError, match="node offsets"):
        store.add_replica(n_nodes=3)


def test_decommission_is_destructive_and_safe(two_rep_store):
    store, _ = two_rep_store
    rid = store.add_replica()
    base = mr.run_job(store, Q_AD).results["n_rows"]
    v0 = store.version
    dropped = store.decommission_replica(rid)
    assert dropped == 0                             # never claimed
    assert store.replicas[rid].retired
    assert store.replicas[rid].cols == {}           # bytes freed
    assert store.live_replica_ids() == [0, 1]
    assert store.version > v0                       # caches invalidated
    assert mr.run_job(store, Q_AD).results["n_rows"] == base
    with pytest.raises(ValueError, match="already retired"):
        store.decommission_replica(rid)
    store.decommission_replica(1)
    with pytest.raises(ValueError, match="last healthy copy"):
        store.decommission_replica(0)


def test_decommission_drops_indexes_and_counts_them(two_rep_store):
    store, _ = two_rep_store
    dropped = store.decommission_replica(1)         # sourceIP replica
    assert dropped == store.n_blocks
    assert store.adaptive_replica_for("sourceIP") is None


def test_decommission_survives_quarantine(two_rep_store):
    store, _ = two_rep_store
    rid = store.add_replica()
    node = int(store.replicas[rid].nodes[0])
    store.quarantine_block(rid, 0)
    assert store.namenode.is_quarantined(0, node)
    store.decommission_replica(rid)                 # rot in quarantine: ok
    assert not store.namenode.is_quarantined(0, node)
    assert store.live_replica_ids() == [0, 1]


def test_template_replica_survives_retirement(two_rep_store):
    store, _ = two_rep_store
    store.add_replica()
    # retire replica 0: template/dtype lookups must not hit its freed cols
    store.decommission_replica(0)
    tmpl = store.template_replica()
    assert tmpl.cols                                # a LIVE replica
    assert mr.run_job(store, Q_VD).results["n_rows"] >= 0


def test_controller_add_then_decommission_cycle(two_rep_store):
    store, _ = two_rep_store
    reg = MetricsRegistry()                         # isolated from REGISTRY
    # cold_ticks must tolerate both the hot-phase rotation length and the
    # claim window (an added replica serves no reads until the NEXT
    # adaptive job claims and builds it)
    ctl = gvn.replicate(store, min_replication=2, max_replication=5,
                        hot_misses=1, cold_ticks=4, registry=reg)
    assert store.replicator is ctl
    adaptive = mr.AdaptiveConfig(offer_rate=1.0)

    # hot phase: adRevenue misses (both replicas claimed elsewhere) -> the
    # job-boundary tick adds a replica; the NEXT adaptive job claims it.
    # Q_VD/Q_SIP interleave so the ORIGINAL replicas stay warm throughout.
    mr.run_job(store, Q_AD, adaptive=adaptive)
    assert ctl.replicas_added == 1
    new_rid = ctl.events[0].replica_id
    assert ctl.events[0].column == "adRevenue"
    assert store.replicas[new_rid].sort_key is None
    mr.run_job(store, Q_VD, adaptive=adaptive)
    mr.run_job(store, Q_SIP, adaptive=adaptive)
    mr.run_job(store, Q_AD, adaptive=adaptive)      # claims + builds new_rid
    assert store.replicas[new_rid].sort_key == "adRevenue"
    assert ctl.replicas_added == 1                  # claimed: no second add
    post = mr.run_job(store, Q_AD, adaptive=adaptive)
    assert post.full_scan_blocks == 0               # index scan on new_rid
    assert ctl.replicas_decommissioned == 0         # every replica warm

    # shifted phase: adRevenue vanishes from the workload — new_rid's heat
    # delta stays 0 for cold_ticks consecutive boundaries -> retired, while
    # the still-hot visitDate/sourceIP replicas survive
    for _ in range(4):
        mr.run_job(store, Q_VD, adaptive=adaptive)
        mr.run_job(store, Q_SIP, adaptive=adaptive)
    assert ctl.replicas_decommissioned == 1
    assert ctl.events[-1].replica_id == new_rid
    assert store.replicas[new_rid].retired
    assert store.live_replica_ids() == [0, 1]
    # floor respected forever after
    for _ in range(4):
        mr.run_job(store, Q_VD, adaptive=adaptive)
    assert store.live_replica_ids() == [0, 1]
    ctl.detach()
    assert store.replicator is None


def test_controller_respects_max_replication(two_rep_store):
    store, _ = two_rep_store
    reg = MetricsRegistry()
    ctl = gvn.replicate(store, max_replication=2, hot_misses=1,
                        registry=reg)
    mr.run_job(store, Q_AD, adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    mr.run_job(store, Q_AD, adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    assert ctl.replicas_added == 0                  # at the ceiling
    assert store.live_replica_ids() == [0, 1]
