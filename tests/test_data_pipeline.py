"""HailDataSource: indexed training-data selection feeding token batches."""
import numpy as np
import pytest

from repro.data.pipeline import CorpusConfig, HailDataSource, build_corpus


@pytest.fixture(scope="module")
def corpus():
    cfg = CorpusConfig(n_docs=512, seq_width=32, rows_per_block=128,
                       partition_size=32, n_domains=8)
    store, stats = build_corpus(cfg, seed=5)
    return cfg, store


def test_selection_uses_index_and_filters(corpus):
    cfg, store = corpus
    src = HailDataSource(store, cfg, select=("domain", 3, 3), batch_size=4)
    assert src.used_index
    assert 0 < src.n_selected < 512
    # roughly 1/8 of docs
    assert abs(src.n_selected - 512 / 8) < 40


def test_batches_have_training_shape(corpus):
    cfg, store = corpus
    src = HailDataSource(store, cfg, select=("quality", 500, 1000),
                         batch_size=4)
    it = iter(src)
    b = next(it)
    assert b["tokens"].shape == (4, cfg.seq_width - 1)
    assert b["labels"].shape == (4, cfg.seq_width - 1)
    # labels are tokens shifted by one
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_unfiltered_selects_everything(corpus):
    cfg, store = corpus
    src = HailDataSource(store, cfg, select=None, batch_size=2)
    assert src.n_selected == 512
