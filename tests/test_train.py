"""Optimizer correctness + end-to-end memorization on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.train.optimizer import (OptCfg, adamw_update, global_norm,
                                   init_opt_state, lr_at)
from repro.train.step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def test_adamw_matches_numpy_reference():
    cfg = OptCfg(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1,
                 clip_norm=1e9, warmup_steps=0, total_steps=10, min_lr_frac=1.0)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    st = init_opt_state(p, cfg)
    new_p, st2, _ = adamw_update(p, g, st, cfg)
    # numpy oracle (step 0)
    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.01 * gn * gn
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    upd = mhat / (np.sqrt(vhat) + 1e-8)
    want = np.asarray(p["w"]) - 1e-2 * (upd + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_grad_clip_scales_update():
    cfg = OptCfg(lr=1.0, clip_norm=0.1, warmup_steps=0, total_steps=2,
                 weight_decay=0.0, min_lr_frac=1.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    assert float(global_norm(g)) == 200.0
    _, _, metrics = adamw_update(p, g, init_opt_state(p, cfg), cfg)
    assert float(metrics["grad_norm"]) == 200.0


def test_lr_schedule_shape():
    cfg = OptCfg(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in (0, 5, 10, 50, 99)]
    assert lrs[0] < lrs[1] < lrs[2]          # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]        # cosine decays
    assert lrs[4] >= 0.1 * 0.99              # floors at min_lr_frac


def test_bf16_optimizer_state_halves_memory():
    cfg32 = OptCfg()
    cfg16 = OptCfg(state_dtype=jnp.bfloat16)
    p = {"w": jnp.zeros((128, 128))}
    m32 = init_opt_state(p, cfg32)["m"]["w"]
    m16 = init_opt_state(p, cfg16)["m"]["w"]
    assert m32.dtype == jnp.float32 and m16.dtype == jnp.bfloat16


def test_tiny_model_memorizes():
    """30 steps on one repeated batch must cut the loss sharply."""
    cfg = get_reduced("llama3.2-1b")
    opt = OptCfg(lr=3e-3, warmup_steps=5, total_steps=30, weight_decay=0.0)
    state = init_train_state(cfg, opt, KEY)
    step = jax.jit(make_train_step(cfg, opt))
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab),
             "labels": jax.random.randint(jax.random.fold_in(KEY, 1),
                                          (4, 32), 0, cfg.vocab)}
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.5, losses[::6]
    assert np.isfinite(losses).all()
