"""Adaptive per-block indexing (LIAH): lazy uploads ship blocks unindexed,
running jobs build the missing clustered indexes incrementally and commit
them back into the BlockStore, and repeated jobs converge from all-full-scan
to all-index-scan with results bit-identical to the eager store throughout.

Also covers the satellite machinery: ``ops.stats_scope`` counter isolation,
incremental root-directory merge, bad-mask cache invalidation on commit,
scheduler charging of index-build work, and the workload-driven claiming of
unkeyed replicas.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import index as idx
from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.schema import ROWID
from repro.kernels import ops
from repro.runtime.cluster import SimulatedCluster
from repro.runtime.scheduler import Task, run_schedule

from conftest import BLOCKS, PART, ROWS

Q1 = q.HailQuery(filter=("visitDate", 7305, 7670), projection=("sourceIP",))


@pytest.fixture()
def lazy_store(uservisits_raw):
    """FRESH unindexed store per test — adaptive jobs mutate it."""
    _, raw = uservisits_raw
    store, _ = up.hail_upload(sc.USERVISITS, raw, index_columns=(),
                              partition_size=PART, n_nodes=6, replication=3)
    return store


def _sorted_rows(res):
    rows = q.collect(res)
    order = np.argsort(rows[ROWID])
    return {k: v[order] for k, v in rows.items()}


# ---------------------------------------------------------------------------
# Lazy upload
# ---------------------------------------------------------------------------


def test_lazy_upload_ships_unindexed(uservisits_raw):
    _, raw = uservisits_raw
    store, stats = up.hail_upload(sc.USERVISITS, raw, index_columns=(),
                                  partition_size=PART, n_nodes=6)
    assert set(stats.phases) == {"hail_lazy"}
    assert stats.n_indexes == 0
    assert stats.wall_s == pytest.approx(sum(stats.phases.values()))
    assert store.replication == 3
    for rep in store.replicas:
        assert rep.sort_key is None
        assert not rep.indexed.any()
    for info in store.namenode.dir_rep.values():
        assert info.sort_key is None
    # no replica qualifies any block for index scan yet
    qp = q.plan(store, Q1)
    assert not qp.index_scan.any()


def test_eager_upload_rejects_conflicting_replication(uservisits_raw):
    _, raw = uservisits_raw
    with pytest.raises(ValueError):
        up.hail_upload(sc.USERVISITS, raw, ["visitDate"], replication=3)


def test_lazy_and_eager_rowsets_match(lazy_store, hail_store):
    qp_l = q.plan(lazy_store, Q1)
    qp_e = q.plan(hail_store, Q1)
    lazy = _sorted_rows(q.read_hail(lazy_store, Q1, qp_l))
    eager = _sorted_rows(q.read_hail(hail_store, Q1, qp_e))
    np.testing.assert_array_equal(lazy[ROWID], eager[ROWID])
    np.testing.assert_array_equal(lazy["sourceIP"], eager["sourceIP"])


# ---------------------------------------------------------------------------
# Convergence under repeated adaptive jobs (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_adaptive_convergence_curve(lazy_store, hail_store):
    cfg = mr.AdaptiveConfig(offer_rate=0.5)
    want = mr.run_job(hail_store, Q1).results["n_rows"]
    eager_rows = _sorted_rows(q.read_hail(hail_store, Q1,
                                          q.plan(hail_store, Q1)))
    jobs_to_converge = math.ceil(1 / cfg.offer_rate)
    modeled, read_bytes, fracs = [], [], []
    for k in range(jobs_to_converge + 2):
        with ops.stats_scope() as s:
            stats = mr.run_job(lazy_store, Q1, adaptive=cfg,
                               reader="kernels")
        # results bit-identical to the eager store at every step
        assert stats.results["n_rows"] == want
        rows = _sorted_rows(q.read_hail_kernels(lazy_store, Q1,
                                                q.plan(lazy_store, Q1)))
        np.testing.assert_array_equal(rows[ROWID], eager_rows[ROWID])
        np.testing.assert_array_equal(rows["sourceIP"], eager_rows["sourceIP"])
        modeled.append(stats.modeled_s)
        read_bytes.append(stats.bytes_read)
        fracs.append(lazy_store.indexed_fraction("visitDate"))
        assert sum(stats.build_s) == pytest.approx(stats.index_build_s)
        if k >= jobs_to_converge:
            # converged: zero full-scan blocks through the fused reader
            assert s.dispatches["full_scan_blocks"] == 0
            assert stats.full_scan_blocks == 0
            assert stats.blocks_indexed == 0
    # indexed fraction is monotone and hits 1; latency curve and bytes read
    # are monotonically non-increasing (modeled_s is deterministic)
    assert fracs == sorted(fracs)
    assert fracs[-1] == 1.0
    assert all(a >= b for a, b in zip(modeled, modeled[1:]))
    assert all(a >= b for a, b in zip(read_bytes, read_bytes[1:]))
    assert read_bytes[-1] < read_bytes[0]


@settings(max_examples=5, deadline=None)
@given(st.sampled_from([0.25, 0.34, 0.5, 1.0]),
       st.sampled_from(["visitDate", "sourceIP", "duration"]),
       st.tuples(st.integers(0, 1 << 16), st.integers(0, 1 << 16)))
def test_adaptive_property(uservisits_raw, offer_rate, col, lohi):
    """For any offer rate, filter column and range: results never change,
    cumulative blocks_indexed is monotone, and the full-scan fraction hits
    zero within ceil(1/offer_rate) jobs."""
    _, raw = uservisits_raw
    store, _ = up.hail_upload(sc.USERVISITS, raw, index_columns=(),
                              partition_size=PART, n_nodes=6)
    lo, hi = min(lohi), max(lohi) + 8000   # keep some selectivity spread
    query = q.HailQuery(filter=(col, lo, hi), projection=("destURL",))
    cfg = mr.AdaptiveConfig(offer_rate=offer_rate)
    first = None
    cumulative = 0
    for _ in range(math.ceil(1 / offer_rate) + 1):
        stats = mr.run_job(store, query, adaptive=cfg)
        if first is None:
            first = stats.results["n_rows"]
        assert stats.results["n_rows"] == first
        assert stats.blocks_indexed >= 0
        cumulative += stats.blocks_indexed
        assert cumulative == int(sum(r.indexed.sum()
                                     for r in store.replicas))
    assert store.indexed_fraction(col) == 1.0
    assert mr.run_job(store, query).full_scan_blocks == 0
    assert cumulative == BLOCKS


def test_adaptive_claims_one_replica_per_workload_key(lazy_store):
    """Different filter columns claim different replicas — the store
    converges toward one clustered index per replica, workload-driven."""
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    q2 = q.HailQuery(filter=("sourceIP", 0, 1 << 30),
                     projection=("visitDate",))
    mr.run_job(lazy_store, Q1, adaptive=cfg)
    mr.run_job(lazy_store, q2, adaptive=cfg)
    assert lazy_store.indexed_fraction("visitDate") == 1.0
    assert lazy_store.indexed_fraction("sourceIP") == 1.0
    keys = [r.sort_key for r in lazy_store.replicas]
    assert keys.count("visitDate") == 1
    assert keys.count("sourceIP") == 1
    assert keys.count(None) == 1
    assert q.plan(lazy_store, Q1).index_scan.all()
    assert q.plan(lazy_store, q2).index_scan.all()


def test_adaptive_noop_on_eager_store(hail_store):
    """Fully indexed store: adaptive mode must neither build nor perturb."""
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    base = mr.run_job(hail_store, Q1)
    adapt = mr.run_job(hail_store, Q1, adaptive=cfg)
    assert adapt.blocks_indexed == 0
    assert adapt.results["n_rows"] == base.results["n_rows"]
    # all replicas claimed by OTHER keys -> no replica to adapt for this col
    q_dur = q.HailQuery(filter=("duration", 0, 5000), projection=("destURL",))
    adapt2 = mr.run_job(hail_store, q_dur, adaptive=cfg)
    assert adapt2.blocks_indexed == 0
    assert hail_store.replica_by_key("duration") is None


def test_max_build_per_job_caps_offers(lazy_store):
    cfg = mr.AdaptiveConfig(offer_rate=1.0, max_build_per_job=1)
    stats = mr.run_job(lazy_store, Q1, adaptive=cfg)
    assert stats.blocks_indexed == 1
    assert lazy_store.indexed_fraction("visitDate") == 1 / BLOCKS


# ---------------------------------------------------------------------------
# Store/index plumbing behind the commit
# ---------------------------------------------------------------------------


def test_merge_block_roots_splices():
    import jax.numpy as jnp
    mins = jnp.zeros((4, 8), jnp.int32)
    new = jnp.arange(16, dtype=jnp.int32).reshape(2, 8)
    out = idx.merge_block_roots(mins, [1, 3], new)
    np.testing.assert_array_equal(np.asarray(out[1]), np.arange(8))
    np.testing.assert_array_equal(np.asarray(out[3]), np.arange(8, 16))
    np.testing.assert_array_equal(np.asarray(out[0]), 0)
    np.testing.assert_array_equal(np.asarray(mins[1]), 0)  # functional


def test_commit_updates_namenode_and_invalidates_bad_mask(lazy_store):
    rid = 0
    before = q._bad_mask(lazy_store, rid)
    mr.run_job(lazy_store, Q1,
               adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    rep = lazy_store.replicas[rid]
    assert rep.sort_key == "visitDate"
    assert rep.indexed.all()
    # namenode Dir_rep advanced with the commit
    for b in range(lazy_store.n_blocks):
        info = lazy_store.namenode.dir_rep[(b, int(rep.nodes[b]))]
        assert info.sort_key == "visitDate"
        assert lazy_store.namenode.get_hosts_with_index(b, "visitDate")
    # bad-mask cache was invalidated: bad rows moved to the sorted tail
    after = q._bad_mask(lazy_store, rid)
    assert after is not before
    r = np.arange(ROWS)[None, :]
    tail = r >= (ROWS - np.asarray(lazy_store.bad_counts)[:, None])
    np.testing.assert_array_equal(np.asarray(after), tail)
    # partition minima of committed blocks are sorted (real root directory)
    mins = np.asarray(rep.mins)
    assert (np.diff(mins[:, :-1], axis=1) >= 0).all()


def test_commit_preserves_per_replica_checksums(lazy_store):
    from repro.core import checksum as ck
    mr.run_job(lazy_store, Q1, adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    rep = lazy_store.replicas[0]
    other = lazy_store.replicas[1]
    # replica 0 re-sorted: its checksums now differ from the untouched one
    assert not bool(np.asarray(
        rep.checksums["sourceIP"] == other.checksums["sourceIP"]).all())
    # and they verify against the committed (sorted) bytes, block by block
    for b in range(lazy_store.n_blocks):
        block_cols = {c: v[b] for c, v in rep.cols.items()}
        sums = {c: v[b] for c, v in rep.checksums.items()}
        assert bool(ck.verify_block(block_cols, sums))


# ---------------------------------------------------------------------------
# Scheduler: index-build work is charged to task durations
# ---------------------------------------------------------------------------


def test_job_tasks_bridge_charges_builds(lazy_store):
    """run_job's measured split/build walls flow into scheduler Tasks and
    the build tax shows up in the simulated makespan."""
    st = mr.run_job(lazy_store, Q1, adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    assert st.blocks_indexed == BLOCKS and st.index_build_s > 0
    tasks = mr.job_tasks(st)
    assert len(tasks) == len(st.split_s)
    assert sum(t.index_build_s for t in tasks) == pytest.approx(
        st.index_build_s)
    cl = lambda: SimulatedCluster(n_nodes=2, map_slots=1, seed=0)
    stripped = [Task(t.task_id, t.duration_s, t.preferred_nodes)
                for t in tasks]
    with_builds = run_schedule(tasks, cl(), spec_factor=None)
    without = run_schedule(stripped, cl(), spec_factor=None)
    assert with_builds.makespan_s > without.makespan_s


def test_scheduler_charges_index_build_time():
    cluster = lambda: SimulatedCluster(n_nodes=2, map_slots=1, seed=0)
    plain = [Task(i, 10.0, preferred_nodes=(i % 2,)) for i in range(4)]
    building = [Task(i, 10.0, preferred_nodes=(i % 2,), index_build_s=5.0)
                for i in range(4)]
    a = run_schedule(plain, cluster(), spec_factor=None)
    b = run_schedule(building, cluster(), spec_factor=None)
    assert b.makespan_s == pytest.approx(a.makespan_s + 2 * 5.0)
    for r in b.runs:
        assert r.end_s - r.start_s == pytest.approx(15.0)


# ---------------------------------------------------------------------------
# stats_scope: per-test dispatch counters, independent of test order
# ---------------------------------------------------------------------------


def test_stats_scope_isolates_and_merges():
    base = ops.DISPATCH_COUNTS["hail_read"]            # whatever ran before
    with ops.stats_scope() as s:
        assert ops.DISPATCH_COUNTS["hail_read"] == 0   # fresh inside
        ops.DISPATCH_COUNTS["hail_read"] += 2
        with ops.stats_scope() as inner:               # scopes nest
            ops.DISPATCH_COUNTS["hail_read"] += 1
        assert inner.dispatches["hail_read"] == 1
        assert ops.DISPATCH_COUNTS["hail_read"] == 3   # merged back
    assert s.dispatches["hail_read"] == 3
    assert ops.DISPATCH_COUNTS["hail_read"] == base + 3  # restored + merged
    with ops.stats_scope(merge=False):
        ops.DISPATCH_COUNTS["hail_read"] += 99
    assert ops.DISPATCH_COUNTS["hail_read"] == base + 3  # discarded


def test_stats_scope_order_independent_counts(hail_store):
    """The same read sequence yields the same counts in every scope, no
    matter what ran before — the old reset_stats() global had to hope no
    other test raced it."""
    qp = q.plan(hail_store, Q1)
    counts = []
    for _ in range(2):
        with ops.stats_scope() as s:
            q.read_hail_kernels(hail_store, Q1, qp)
            q.read_hail_kernels(hail_store, Q1, qp, [0, 2])
        counts.append((s.dispatches["hail_read"],
                       s.dispatches["index_scan_blocks"]))
    assert counts[0] == counts[1] == (2, BLOCKS + 2)
