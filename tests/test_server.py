"""HailServer: shared-scan batching, admission control, the governor-
integrated hot-block cache, and cache-invalidation races.

The acceptance scenario (ISSUE 4): 8 concurrent mixed-tenant queries over a
shared replica must issue ONE fused dispatch per (split, batch) — verified
via ``reader_stats`` — and return row-sets identical to 8 serial ``run_job``
calls, including under mid-batch demotion and node failover.  The property
test drives randomized interleavings of flushes, adaptive commits, direct
demotions and node failures against an uncached eager-store oracle.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import governor as gv
from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows
from repro.core.schema import ROWID
from repro.kernels import ops
from repro.runtime import jobserver as js
from repro.runtime.cluster import SimulatedCluster
from repro.runtime.scheduler import run_schedule

from conftest import BLOCKS, PART

RANGES = [(7305, 7670), (0, 100), (5000, 20000), (7, 7),
          (123, 9999), (0, 1 << 30), (42, 4242), (1000, 8001)]
QUERIES = [q.HailQuery(filter=("visitDate", lo, hi),
                       projection=("sourceIP",)) for lo, hi in RANGES]


@pytest.fixture()
def served_store(uservisits_raw):
    """FRESH indexed store per test — the server attaches a cache to it."""
    _, raw = uservisits_raw
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              partition_size=PART, n_nodes=6)
    return store


@pytest.fixture()
def lazy_store(uservisits_raw):
    _, raw = uservisits_raw
    store, _ = up.hail_upload(sc.USERVISITS, raw, index_columns=(),
                              partition_size=PART, n_nodes=6, replication=3)
    return store


def _oracle_rows(store, query):
    rows = q.collect(q.read_hail(store, query, q.plan(store, query)))
    order = np.argsort(rows[ROWID])
    return {k: v[order] for k, v in rows.items()}


def _assert_ticket_matches(ticket, want):
    assert ticket.status == "done"
    got = ticket.result.rows
    order = np.argsort(got[ROWID])
    assert ticket.result.n_rows == len(want[ROWID])
    for c in want:
        np.testing.assert_array_equal(got[c][order], want[c])


# ---------------------------------------------------------------------------
# Acceptance: one fused dispatch per (split, batch), row-sets == serial jobs
# ---------------------------------------------------------------------------


def test_shared_scan_batch_acceptance(served_store):
    # serial oracle FIRST: 8 independent run_job calls
    serial_rows = []
    with ops.stats_scope() as s_serial:
        for qq in QUERIES:
            st_ = mr.run_job(served_store, qq, reader="kernels")
            serial_rows.append(st_.results["n_rows"])
    serial_dispatches = s_serial.dispatches["hail_read"]

    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    tickets = [server.submit(qq, tenant=f"tenant{i % 3}")
               for i, qq in enumerate(QUERIES)]
    with ops.stats_scope() as s:
        fl = server.flush()
    # all 8 compatible queries formed ONE batch: one fused dispatch per
    # (split, batch), 8x fewer than the serial jobs issued
    assert fl.n_batches == 1 and fl.batch_sizes == [8]
    assert s.dispatches["hail_read"] == fl.n_splits
    assert s.dispatches["hail_read_batch"] == fl.n_splits
    assert serial_dispatches == 8 * fl.n_splits
    assert s.dispatches["pax_scan"] == 0 and s.dispatches["index_search"] == 0
    # row-sets identical to the serial jobs
    for ticket, qq, n_serial in zip(tickets, QUERIES, serial_rows):
        assert ticket.result.n_rows == n_serial
        _assert_ticket_matches(ticket, _oracle_rows(served_store, qq))
    assert fl.n_queries == 8 and fl.bytes_read > 0


def test_batch_width_compiles_once(served_store):
    """A fixed max_batch means ONE reader variant: later flushes with new
    ranges at the same width must not retrace."""
    server = js.HailServer(served_store, js.ServerConfig(max_batch=4))
    with ops.stats_scope() as s:
        for shift in (0, 1, 2):
            for lo, hi in RANGES[:4]:
                server.submit(q.HailQuery(
                    filter=("visitDate", lo + shift, hi + shift),
                    projection=("sourceIP",)))
            fl = server.flush()
            assert fl.batch_sizes == [4]
    assert s.traces["hail_read_batch"] <= 1
    assert s.dispatches["hail_read"] == 3 * fl.n_splits


def test_admission_control_per_tenant(served_store):
    cfg = js.ServerConfig(max_pending_per_tenant=2, max_pending_total=3)
    server = js.HailServer(served_store, cfg)
    server.submit(QUERIES[0], tenant="a")
    server.submit(QUERIES[1], tenant="a")
    with pytest.raises(js.AdmissionError):
        server.submit(QUERIES[2], tenant="a")       # tenant quota
    server.submit(QUERIES[2], tenant="b")
    with pytest.raises(js.AdmissionError):
        server.submit(QUERIES[3], tenant="c")       # global quota
    assert server.pending_count() == 3
    server.flush()
    assert server.pending_count() == 0
    server.submit(QUERIES[3], tenant="a")           # quota freed by flush
    fl = server.flush()
    assert fl.n_queries == 1


def test_incompatible_queries_split_batches(served_store):
    """Different filter columns (or projections) cannot share a scan — they
    form separate batches; an unfiltered query runs as a singleton."""
    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    server.submit(QUERIES[0])
    server.submit(QUERIES[1])
    server.submit(q.HailQuery(filter=("sourceIP", 0, 1 << 30),
                              projection=("visitDate",)))
    server.submit(q.HailQuery(filter=None, projection=("sourceIP",)))
    fl = server.flush()
    assert fl.n_batches == 3 and sorted(fl.batch_sizes) == [1, 1, 2]
    for t in server.tickets:
        _assert_ticket_matches(t, _oracle_rows(served_store, t.query))


def test_flush_under_failover(served_store):
    """Mid-flush node death: lost splits re-plan to per-block retries (same
    path as run_job), every retry still goes through the fused batch reader,
    and row-sets stay exact."""
    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    tickets = [server.submit(qq) for qq in QUERIES]
    with ops.stats_scope() as s:
        fl = server.flush(fail_node_at=0.5)
    assert fl.rescheduled_tasks > 0
    assert s.dispatches["hail_read"] == fl.n_splits   # retries fused too
    assert not served_store.namenode.dead             # revived after flush
    for ticket, qq in zip(tickets, QUERIES):
        _assert_ticket_matches(ticket, _oracle_rows(served_store, qq))


# ---------------------------------------------------------------------------
# Shared adaptive quantum + mid-batch demotion
# ---------------------------------------------------------------------------


def test_shared_build_quantum_across_tenants(lazy_store):
    """Concurrent tenants share ONE offer quantum per flush: 4 queries in a
    batch advance convergence by one job's worth, not 4 jobs' worth."""
    cfg = mr.AdaptiveConfig(offer_rate=0.5)
    quantum = mr.adaptive_quantum(lazy_store, cfg)
    server = js.HailServer(lazy_store, js.ServerConfig(max_batch=4,
                                                       adaptive=cfg))
    for i in range(4):
        server.submit(QUERIES[i], tenant=f"t{i}")
    fl = server.flush()
    assert fl.blocks_indexed == quantum               # one quantum, shared
    assert lazy_store.indexed_fraction("visitDate") == quantum / BLOCKS
    # convergence model unchanged: ceil(1/offer_rate) flushes to 1.0.
    # Ranges are PERTURBED per flush: an exact repeat would be served from
    # the result cache (zero scans — correct, but no piggyback builds;
    # convergence advances on ranges that actually scan)
    def shifted(i, r):
        col, lo, hi = QUERIES[i].filter
        return q.HailQuery(filter=(col, lo + r, hi + r),
                           projection=QUERIES[i].projection)
    for r in range(1, math.ceil(1 / cfg.offer_rate)):
        for i in range(4):
            server.submit(shifted(i, r), tenant=f"t{i}")
        server.flush()
    assert lazy_store.indexed_fraction("visitDate") == 1.0
    # converged: the next flush is pure index scan, zero build
    for i in range(4):
        server.submit(shifted(i, 100), tenant=f"t{i}")
    with ops.stats_scope() as s:
        fl = server.flush()
    assert fl.blocks_indexed == 0
    assert s.dispatches["full_scan_blocks"] == 0
    for t in server.tickets:
        _assert_ticket_matches(t, _oracle_rows(lazy_store, t.query))


def test_mid_batch_demotion_keeps_rowsets_exact(lazy_store, served_store):
    """Budget pressure DURING a flush: the shifted batch's builds evict the
    old column's replica mid-batch, invalidating its cache entries — and
    every ticket of the flush still matches the eager oracle."""
    gv.govern(lazy_store, max_indexed_blocks=BLOCKS)
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    # result_cache off: this test warms and checks the BLOCK cache — the
    # result tier would serve the repeat flush before it touches tier 1
    server = js.HailServer(lazy_store, js.ServerConfig(max_batch=4,
                                                       adaptive=cfg,
                                                       result_cache=False))
    for i in range(4):
        server.submit(QUERIES[i], tenant=f"t{i}")
    server.flush()                                    # converge visitDate
    assert lazy_store.indexed_fraction("visitDate") == 1.0

    # warm the cache on the victim replica (pure index scans, converged —
    # no adaptive work left on visitDate) so the demotion must invalidate
    for i in range(4):
        server.submit(QUERIES[i], tenant=f"t{i}")
    warm = server.flush()
    assert warm.blocks_indexed == 0 and warm.blocks_demoted == 0
    assert len(server.cache) > 0
    inval0 = server.cache.stats.invalidations

    shift = [q.HailQuery(filter=("sourceIP", lo, hi),
                         projection=("visitDate",))
             for lo, hi in [(0, 1 << 30), (1 << 10, 1 << 20),
                            (0, 1 << 16), (5, 5)]]
    for i, qq in enumerate(shift):
        server.submit(qq, tenant=f"t{i}")
    fl = server.flush()
    assert fl.blocks_demoted == BLOCKS                # mid-batch eviction
    assert fl.blocks_indexed > 0                      # re-keyed for the shift
    assert lazy_store.total_indexed_blocks() <= BLOCKS
    assert server.cache.stats.invalidations > inval0  # cache stayed coherent
    for t in server.tickets:
        _assert_ticket_matches(t, _oracle_rows(served_store, t.query))
    # old workload still answers exactly (full scan over demoted replica)
    server.submit(QUERIES[0])
    server.flush()
    _assert_ticket_matches(server.tickets[-1],
                           _oracle_rows(served_store, QUERIES[0]))


def test_row_ascii_store_served_via_hadoop_reader(uservisits_raw):
    """A row-layout (Hadoop baseline) store is servable too: queries run as
    singleton batches through read_hadoop, results equal to run_job."""
    _, raw = uservisits_raw
    store, _ = up.hdfs_upload(sc.USERVISITS, raw, replication=3, n_nodes=6)
    server = js.HailServer(store, js.ServerConfig(max_batch=8))
    t_filtered = server.submit(QUERIES[0])
    t_all = server.submit(q.HailQuery(filter=None, projection=("sourceIP",)))
    fl = server.flush()
    assert fl.n_batches == 2 and fl.batch_sizes == [1, 1]
    for t in (t_filtered, t_all):
        base = mr.run_job(store, t.query)
        assert t.result.n_rows == base.results["n_rows"] > 0
        got = q.collect(q.read_hadoop(store, t.query))
        order, gorder = (np.argsort(got[ROWID]),
                         np.argsort(t.result.rows[ROWID]))
        for c in t.query.projection + (ROWID,):
            np.testing.assert_array_equal(got[c][order],
                                          t.result.rows[c][gorder])


def test_one_flush_cannot_satisfy_its_own_hysteresis(lazy_store):
    """The governor's job boundary is the FLUSH, not the batch: a column
    seen for the first time — however many batches its flush takes — must
    not demote a warm index; the SECOND flush may."""
    gv.govern(lazy_store, max_indexed_blocks=10 * BLOCKS)
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    for col in ("visitDate", "sourceIP", "adRevenue"):
        mr.run_job(lazy_store, q.HailQuery(filter=(col, 0, 1 << 30),
                                           projection=("duration",)),
                   adaptive=cfg)
    assert all(r.sort_key is not None for r in lazy_store.replicas)
    server = js.HailServer(lazy_store, js.ServerConfig(max_batch=2,
                                                       adaptive=cfg))
    # 3 incompatible duration queries -> 2+ batches in ONE first-ever flush
    server.submit(q.HailQuery(filter=("duration", 0, 4000),
                              projection=("sourceIP",)))
    server.submit(q.HailQuery(filter=("duration", 0, 4000),
                              projection=("visitDate",)))
    server.submit(q.HailQuery(filter=("duration", 7, 7),
                              projection=("sourceIP",)))
    fl = server.flush()
    assert fl.n_batches >= 2
    assert fl.blocks_demoted == 0                 # one-off workload: no harm
    assert all(lazy_store.indexed_fraction(c) == 1.0
               for c in ("visitDate", "sourceIP", "adRevenue"))
    # the workload returns: the second distinct flush (a NEW job boundary,
    # so the first flush's misses now count as prior) crosses the threshold.
    # The range is perturbed — an exact repeat would be answered from the
    # result cache, which (correctly) never claims or demotes anything
    server.submit(q.HailQuery(filter=("duration", 0, 4001),
                              projection=("sourceIP",)))
    fl = server.flush()
    assert fl.blocks_demoted == BLOCKS
    assert lazy_store.indexed_fraction("duration") == 1.0


# ---------------------------------------------------------------------------
# Governor-integrated cache
# ---------------------------------------------------------------------------


def test_cache_traffic_feeds_access_log(served_store):
    """Cached reads are still governed traffic: the second (all-hit) flush
    advances the AccessLog exactly like the first (all-miss) one."""
    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    rid = served_store.replica_for("visitDate")

    def log_hits():
        rec = served_store.access_log.get(rid, "visitDate") \
            if served_store.access_log else None
        return (rec.hits, rec.last_used) if rec else (0, 0)

    for qq in QUERIES:
        server.submit(qq)
    server.flush()
    hits1, used1 = log_hits()
    for qq in QUERIES:
        server.submit(qq)
    fl2 = server.flush()
    hits2, used2 = log_hits()
    assert fl2.cache_misses == 0 and fl2.cache_hits == fl2.n_splits
    assert hits2 - hits1 == hits1 > 0        # same attribution, cached
    assert used2 > used1                     # recency advanced: not LRU-cold
    # the second flush was the result tier's free lunch, and its replayed
    # attribution is what kept the AccessLog deltas above exact
    assert fl2.result_cache_hits == len(QUERIES) and fl2.n_splits == 0


# ---------------------------------------------------------------------------
# Result cache: the free-lunch tier
# ---------------------------------------------------------------------------


def test_result_cache_free_lunch_exact_and_subsumed(served_store):
    """A repeated range — and a narrower range subsumed by a cached one
    when the filter column is projected — must be answered with ZERO fused
    reader dispatches and rows identical to the uncached oracle."""
    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    wide = q.HailQuery(filter=("visitDate", 0, 1 << 30),
                       projection=("visitDate", "sourceIP"))
    t_wide = server.submit(wide)
    server.flush()
    assert not t_wide.result.from_cache

    t_rep = server.submit(wide)              # exact repeat
    with ops.stats_scope() as s:
        fl = server.flush()
    assert t_rep.result.from_cache and fl.n_splits == 0
    assert s.dispatches["hail_read"] == 0
    assert s.dispatches["hail_read_batch"] == 0
    assert fl.result_cache_hits == 1 and fl.result_cache_misses == 0
    _assert_ticket_matches(t_rep, _oracle_rows(served_store, wide))

    narrow = q.HailQuery(filter=("visitDate", 7305, 7670),
                         projection=("visitDate", "sourceIP"))
    t_nar = server.submit(narrow)            # subsumed by the cached range
    with ops.stats_scope() as s:
        server.flush()
    assert t_nar.result.from_cache
    assert s.dispatches["hail_read"] == 0
    assert server.result_cache.stats.subsumed_hits == 1
    _assert_ticket_matches(t_nar, _oracle_rows(served_store, narrow))

    # filter column NOT projected: the cached rows can't be re-filtered,
    # so subsumption must NOT fire — the query scans and stays exact
    nar2 = q.HailQuery(filter=("visitDate", 7305, 7670),
                       projection=("sourceIP",))
    t3 = server.submit(nar2)
    server.flush()
    assert not t3.result.from_cache
    _assert_ticket_matches(t3, _oracle_rows(served_store, nar2))


def test_result_cache_counters_innermost_stats_scope(served_store):
    """reader_stats under NESTED stats_scope(): a result-cache
    short-circuit hit lands in the INNERMOST scope (the counters are
    looked up at call time), and merges outward on exit — same contract
    as every other reader counter."""
    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    server.submit(QUERIES[0])
    server.flush()                           # fill
    server.submit(QUERIES[0])
    with ops.stats_scope() as outer:
        with ops.stats_scope() as inner:
            server.flush()                   # hit inside the inner scope
            inner_hits_live = ops.DISPATCH_COUNTS["result_cache_hits"]
        outer_hits_before_exit = dict(outer.dispatches).get(
            "result_cache_hits", 0)
    assert inner.dispatches["result_cache_hits"] == 1 == inner_hits_live
    assert inner.dispatches["result_cache_misses"] == 0
    assert outer_hits_before_exit == 1       # merged up when inner exited
    assert outer.dispatches["result_cache_hits"] == 1
    # block-cache counters obey the same innermost-scope rule: QUERIES[4]
    # misses the result tier (new range, filter col not projected so no
    # subsumption) but HITS the block cache (same col+proj gather key as
    # the QUERIES[0] fill).  A LIVE range is required here — a dead one
    # like QUERIES[1] now prunes every split and issues zero gathers.
    server.submit(QUERIES[4])
    with ops.stats_scope() as outer2:
        with ops.stats_scope() as inner2:
            server.flush()
    assert inner2.dispatches["result_cache_misses"] == 1
    assert inner2.dispatches["cache_hits"] > 0
    assert (outer2.dispatches["cache_hits"]
            == inner2.dispatches["cache_hits"])


def test_cache_capacity_scan_resistant_admission(served_store):
    """A capacity below the working set forces the admission filter to
    REJECT one-touch candidates instead of thrashing the residents (the
    pure-LRU predecessor evicted every resident and hit 0.0 here); the
    resident half keeps hitting, so the rate is strictly between 0 and 1.
    result_cache off: repeat flushes must exercise tier 1."""
    big = js.HailServer(served_store, js.ServerConfig(max_batch=1,
                                                      result_cache=False))
    for qq in QUERIES[:4]:
        big.submit(qq)
    big.flush()
    full_bytes = big.cache.stats.bytes_cached
    assert full_bytes > 0

    # an explicit cache_bytes budget replaces the attached unbounded cache
    # (a silently inherited unbounded cache would make the budget a no-op)
    server = js.HailServer(served_store, js.ServerConfig(
        max_batch=1, cache_bytes=full_bytes // 2, result_cache=False))
    small_cache = server.cache
    assert small_cache is served_store.block_cache is not big.cache
    assert small_cache.capacity_bytes == full_bytes // 2
    for _ in range(2):
        for qq in QUERIES[:4]:
            server.submit(qq)
        server.flush()
    assert small_cache.stats.admission_rejects > 0
    assert small_cache.stats.bytes_cached <= full_bytes // 2
    assert 0.0 < small_cache.stats.hit_rate < 1.0
    assert small_cache.recount() == small_cache.stats.bytes_cached
    # same budget again: the existing cache is REUSED, not reset
    again = js.HailServer(served_store, js.ServerConfig(
        cache_bytes=full_bytes // 2))
    assert again.cache is small_cache


def test_commit_and_demote_invalidate_cache(lazy_store):
    """The store's destructive transitions drop the touched replica's cache
    entries (a cached read can never observe a half-committed replica)."""
    server = js.HailServer(lazy_store, js.ServerConfig(max_batch=2))
    server.submit(QUERIES[0])
    server.submit(QUERIES[1])
    server.flush()
    assert len(server.cache) > 0
    mr._build_block_indexes(lazy_store, 0, list(range(BLOCKS)), "visitDate",
                            partition_size=PART)
    assert server.cache.stats.invalidations > 0
    inval = server.cache.stats.invalidations
    server.submit(QUERIES[0])
    server.flush()                            # re-fills from the new state
    _assert_ticket_matches(server.tickets[-1],
                           _oracle_rows(lazy_store, QUERIES[0]))
    lazy_store.demote_replica(0)
    assert server.cache.stats.invalidations > inval
    server.submit(QUERIES[0])
    server.flush()
    _assert_ticket_matches(server.tickets[-1],
                           _oracle_rows(lazy_store, QUERIES[0]))


# ---------------------------------------------------------------------------
# Scheduler bridge: shared-scan throughput
# ---------------------------------------------------------------------------


def test_flush_tasks_throughput_bridge(served_store):
    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    for qq in QUERIES:
        server.submit(qq)
    fl = server.flush()
    tasks = js.flush_tasks(fl)
    assert len(tasks) == fl.n_splits
    assert all(t.n_queries == 8 for t in tasks)
    res = run_schedule(tasks, SimulatedCluster(n_nodes=4, map_slots=2),
                       spec_factor=None)
    # (query, split) answers, not distinct queries: Q * S
    assert res.n_query_answers == 8 * fl.n_splits
    assert res.makespan_s > 0


# ---------------------------------------------------------------------------
# Pallas interpret-mode runtime flag (satellite)
# ---------------------------------------------------------------------------


def test_interpret_env_flag_parsing(monkeypatch):
    for raw, want in [("1", True), ("true", True), ("", True),
                      ("0", False), ("false", False), ("OFF", False),
                      ("No", False)]:
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", raw)
        assert ops._env_interpret() is want, raw
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert ops._env_interpret() is True      # container default: interpret


def test_set_interpret_flips_and_clears_caches(served_store):
    assert ops.interpret_mode() is True
    try:
        ops.set_interpret(False)             # the real-TPU flip, at runtime
        assert ops.interpret_mode() is False
    finally:
        ops.set_interpret(True)
    assert ops.interpret_mode() is True
    # reader still correct after the cache-clearing round trip
    qp = q.plan(served_store, QUERIES[0])
    a = q.read_hail(served_store, QUERIES[0], qp)
    b = q.read_hail_kernels(served_store, QUERIES[0], qp)
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


# ---------------------------------------------------------------------------
# Property test: cache-invalidation races (commits, demotions, failures)
# ---------------------------------------------------------------------------

P_ROWS, P_PART = 256, 64
VMAX = 1 << 20


def _make_store_pair(seed, blocks=3):
    schema = sc.Schema("srv", tuple(sc.Column(f"c{i}") for i in range(3)))
    r = np.random.default_rng(seed)
    cols = {c.name: r.integers(0, VMAX, P_ROWS * blocks, dtype=np.int32)
            for c in schema.columns}
    raw = format_rows(schema, cols, bad_fraction=0.01,
                      seed=seed + 1).reshape(blocks, P_ROWS, -1)
    eager, _ = up.hail_upload(schema, raw, ["c0", "c1"],
                              partition_size=P_PART, n_nodes=4)
    lazy, _ = up.hail_upload(schema, raw, index_columns=(), replication=2,
                             partition_size=P_PART, n_nodes=4)
    return schema, eager, lazy


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 2**31 - 1),                 # data + schedule seed
       st.sampled_from([0.5, 1.0]),               # offer rate
       st.integers(2, 4))                         # queries per flush
def test_server_matches_uncached_oracle_under_races(seed, offer_rate, n_q):
    """Randomized interleavings of server flushes, adaptive index commits,
    direct demotions, node failures, quarantines and repairs: every ticket
    of every flush must equal the UNCACHED single-query oracle (fresh read
    over an eager, never-mutated store) — neither tier may serve stale
    replica state.  Ranges REPEAT (~half are drawn from history), so
    result-cache hits are exercised across every destructive transition."""
    schema, eager, lazy = _make_store_pair(seed)
    gv.govern(lazy, max_indexed_blocks=lazy.n_blocks)
    cfg = mr.AdaptiveConfig(offer_rate=offer_rate)
    server = js.HailServer(lazy, js.ServerConfig(max_batch=4, adaptive=cfg))
    rng = np.random.default_rng(seed ^ 0x5eed)
    verified = 0
    history: list[tuple] = []                  # (col, lo, hi) seen so far
    for step in range(6):
        col = ("c0", "c1")[int(rng.integers(0, 2))]
        qs = []
        for _ in range(n_q):
            if history and rng.random() < 0.5:   # repeat: result-cache path
                col_h, lo, hi = history[int(rng.integers(0, len(history)))]
                flt = (col_h, lo, hi)
            else:
                lo, hi = sorted(rng.integers(0, VMAX, 2).tolist())
                flt = (col, int(lo), int(hi))
            history.append(flt)
            qs.append(q.HailQuery(filter=flt, projection=("c2",)))
            server.submit(qs[-1], tenant=f"t{int(rng.integers(0, 3))}")
        action = int(rng.integers(0, 6))
        if action == 0:                        # race: node death mid-flush
            server.flush(fail_node_at=float(rng.uniform(0.1, 0.9)))
        elif action == 1:                      # race: serial adaptive job
            mr.run_job(lazy, qs[0], adaptive=cfg)   # commits mid-workload
            server.flush()
        elif action == 2:                      # race: direct demotion
            keyed = [i for i, r in enumerate(lazy.replicas)
                     if r.sort_key is not None and r.indexed.any()]
            if keyed:
                lazy.demote_replica(keyed[0])
            server.flush()
        elif action == 3:                      # race: quarantine a block
            b = int(rng.integers(0, lazy.n_blocks))
            alive = lazy.alive_replica_ids(b)
            if len(alive) >= 2:                # never strand the block
                lazy.quarantine_block(alive[0], b)
            server.flush()
            # heal before the next step: a LATER node-death step hitting
            # the sole surviving copy would (correctly) raise typed
            # UnrecoverableDataError and abort that flush — that
            # composition is test_fault's chaos subject, not this one's
            lazy.repair_blocks()
        elif action == 4:                      # race: repair what's hurt
            lazy.repair_blocks()
            server.flush()
        else:
            server.flush()
        for t in server.tickets[verified:]:    # results are immutable —
            _assert_ticket_matches(t, _oracle_rows(eager, t.query))
        verified = len(server.tickets)         # verify each exactly once
        assert lazy.total_indexed_blocks() <= lazy.n_blocks
    # repeats flowed through the result tier (hit or checked-and-missed) —
    # whether a given repeat HITS depends on the interleaving of
    # destructive transitions, which is exactly the point of the test
    assert (server.result_cache.stats.hits
            + server.result_cache.stats.misses) > 0


# ---------------------------------------------------------------------------
# Streaming completion, flush lifecycle fixes, and the async frontend (PR 8)
# ---------------------------------------------------------------------------

import jax.numpy as jnp

from repro.core.fault import FaultInjector
from repro.runtime.scheduler import Task
from repro.runtime.scrubber import Scrubber

# ranges DEAD against visitDate's [7000, 12000) domain vs provably live ones
DEAD_IDX = [i for i, (lo, hi) in enumerate(RANGES) if hi < 7000]
WIDE_IDX = RANGES.index((0, 1 << 30))


def test_streaming_per_query_completion(served_store):
    """A batch member live on no split (dead range) finalizes BEFORE any
    member that must wait on a scan barrier, each ticket's live-split set
    rides in ``queries_of_split``, and every row-set still matches the
    serial oracle."""
    server = js.HailServer(served_store,
                           js.ServerConfig(max_batch=8, result_cache=False))
    tickets = [server.submit(qq) for qq in QUERIES]
    fl = server.flush()
    for t in tickets:
        _assert_ticket_matches(t, _oracle_rows(served_store, t.query))
    # every ticket streamed a completion timestamp
    assert set(fl.query_done_s) == {t.ticket_id for t in tickets}
    # the live map is aligned with the executed splits and is exact at the
    # extremes: dead ranges ride no split, the full-domain range rides all
    assert len(fl.queries_of_split) == fl.n_splits == len(fl.split_s)
    dead_ids = {tickets[i].ticket_id for i in DEAD_IDX}
    wide_id = tickets[WIDE_IDX].ticket_id
    for live in fl.queries_of_split:
        assert wide_id in live
        assert not dead_ids & set(live)
    # dead-range members finalized before any scan-bound member
    dead_done = max(fl.query_done_s[i] for i in dead_ids)
    live_done = min(v for k, v in fl.query_done_s.items()
                    if k not in dead_ids)
    assert dead_done <= live_done
    # the scheduler bridge carries the same dependency sets
    tasks = js.flush_tasks(fl)
    sched = run_schedule(tasks, SimulatedCluster(n_nodes=4), None)
    assert set().union(*fl.queries_of_split) == set(
        sched.query_completion_s)
    assert all(i not in sched.query_completion_s for i in dead_ids)


def test_dead_range_batch_prunes_every_split(served_store):
    """A batch whose members all miss every block's key range dispatches
    ZERO fused reads — and the empty answers carry the STORED dtypes, not
    a hardcoded int32 (regression: the empty-assembly fallback)."""
    for rep in served_store.replicas:
        rep.cols["adRevenue"] = rep.cols["adRevenue"].astype(jnp.float32)
    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    t1 = server.submit(q.HailQuery(filter=("visitDate", 7, 7),
                                   projection=("adRevenue",)))
    t2 = server.submit(q.HailQuery(filter=("visitDate", 0, 100),
                                   projection=("adRevenue",)))
    with ops.stats_scope() as s:
        fl = server.flush()
    assert s.dispatches["hail_read"] == 0 and fl.n_splits == 0
    for t in (t1, t2):
        assert t.status == "done" and t.result.n_rows == 0
        assert t.result.rows["adRevenue"].dtype == np.float32
        assert t.result.rows[ROWID].dtype == np.int32
        assert len(t.result.rows["adRevenue"]) == 0


def test_unrecoverable_batch_fails_typed_not_stranded(served_store):
    """Mid-flush ``UnrecoverableDataError``: the failed batch's tickets get
    a TYPED terminal status (never stranded "queued"), result-cache-served
    tickets of the same flush still complete, the injected-failure node is
    revived, and the boundary scrub still ticks (regression: flush() used
    to propagate and strand everything)."""
    scrub = Scrubber(served_store).attach()
    # no block cache: a warm hit would serve the pre-corruption decode and
    # mask the fault (hits legitimately skip re-verification)
    server = js.HailServer(served_store,
                           js.ServerConfig(max_batch=8, cache=False))
    warm = server.submit(QUERIES[0])
    server.flush()                               # clean fill of the result tier
    assert warm.status == "done"
    ticks0 = scrub.stats.ticks

    # silent corruption of EVERY replica of one block: any scan that plans
    # across it is unrecoverable by construction
    FaultInjector(served_store, seed=3).corrupt_replicas(
        2, served_store.replication, "visitDate")
    hit = server.submit(QUERIES[0])              # result tier: no scan needed
    doomed = server.submit(QUERIES[WIDE_IDX])
    fl = server.flush(fail_node_at=0.0)

    assert hit.status == "done" and hit.result.from_cache
    assert doomed.status == "failed" and doomed.result is None
    assert "block" in doomed.error
    assert fl.failed_queries == [doomed.ticket_id]
    assert not any(t.status == "queued" for t in server.tickets)
    assert not served_store.namenode.dead        # revived in the finally
    assert scrub.stats.ticks == ticks0 + 1       # boundary scrub still ran
    assert fl.scrub_s > 0.0


def test_result_cache_hit_is_mutation_proof(served_store):
    """A caller scribbling on a served answer RAISES instead of silently
    corrupting every future hit for that key (regression: hits aliased
    cache-owned arrays through a shallow dict copy)."""
    server = js.HailServer(served_store, js.ServerConfig(max_batch=8))
    server.submit(QUERIES[0])
    server.flush()                               # fill
    t_hit = server.submit(QUERIES[0])
    server.flush()
    assert t_hit.result.from_cache and t_hit.result.n_rows > 0
    with pytest.raises(ValueError):
        t_hit.result.rows["sourceIP"][:] = -1
    with pytest.raises(ValueError):
        t_hit.result.rows[ROWID][0] = 0
    # and the key keeps serving the exact answer
    t2 = server.submit(QUERIES[0])
    server.flush()
    assert t2.result.from_cache
    _assert_ticket_matches(t2, _oracle_rows(served_store, QUERIES[0]))


def test_flush_tasks_charges_demote_residue():
    """Demotion wall carried by no executed split must still reach the
    scheduler bridge: charged onto the first task, or onto a synthetic
    zero-duration task when the flush executed none."""
    fl = js.FlushStats(n_queries=1, n_batches=1, n_splits=0, batch_sizes=[1])
    fl.demote_residue_s = 0.25
    tasks = js.flush_tasks(fl)
    assert len(tasks) == 1
    assert tasks[0].duration_s == 0.0 and tasks[0].rekey_s == 0.25
    assert run_schedule(tasks, SimulatedCluster(n_nodes=2), None
                        ).makespan_s == pytest.approx(0.25)

    fl2 = js.FlushStats(n_queries=2, n_batches=1, n_splits=2,
                        batch_sizes=[2])
    fl2.split_s, fl2.build_s = [0.5, 0.5], [0.0, 0.0]
    fl2.demote_s, fl2.batch_of_split = [0.0, 0.1], [2, 2]
    fl2.queries_of_split = [(0, 1), (1,)]
    fl2.demote_residue_s = 0.25
    tasks2 = js.flush_tasks(fl2)
    assert len(tasks2) == fl2.n_splits           # no synthetic task
    assert tasks2[0].rekey_s == pytest.approx(0.25)
    assert tasks2[0].query_ids == (0, 1) and tasks2[1].query_ids == (1,)


def test_demote_wall_survives_pruned_and_terminal_batches(
        served_store, monkeypatch):
    """The demotion wall paid at claim time never vanishes, whether every
    split after the claim is dead-pruned or the batch dies terminally
    (regression: it was only charged when a dispatch succeeded)."""
    monkeypatch.setattr(js.mr, "claim_adaptive_replica",
                        lambda store, col, quantum: (None, 1, 0.5))
    cfg = js.ServerConfig(max_batch=8, result_cache=False,
                          adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    # every split dead-pruned: the wall lands in the flush residue
    server = js.HailServer(served_store, cfg)
    server.submit(q.HailQuery(filter=("visitDate", 7, 7),
                              projection=("sourceIP",)))
    fl = server.flush()
    assert fl.n_splits == 0
    assert fl.demote_residue_s == pytest.approx(0.5)
    assert sum(t.rekey_s for t in js.flush_tasks(fl)) == pytest.approx(0.5)

    # batch dies terminally: the wall still reaches the bridge
    FaultInjector(served_store, seed=5).corrupt_replicas(
        1, served_store.replication, "visitDate")
    doomed = server.submit(QUERIES[WIDE_IDX])
    fl2 = server.flush()
    assert doomed.status == "failed"
    assert (sum(fl2.demote_s) + fl2.demote_residue_s
            == pytest.approx(0.5))
    assert (sum(t.rekey_s for t in js.flush_tasks(fl2))
            == pytest.approx(0.5))


# ---------------------------------------------------------------------------
# ServerFrontend: auto-flush, streaming latency, weighted-fair admission
# ---------------------------------------------------------------------------


def test_frontend_window_trigger_and_drain(served_store):
    """The oldest-pending window fires the flush (not the caller), later
    arrivals queue for the next cycle, and every answer matches the serial
    oracle with a per-query latency."""
    server = js.HailServer(served_store,
                           js.ServerConfig(result_cache=False))
    fe = js.ServerFrontend(server, js.FlushPolicy(window_s=1.0))
    for i, dt in [(0, 0.0), (2, 0.1), (4, 0.2)]:
        fe.offer(QUERIES[i], at=dt)
    assert fe.flushes == [] and fe.queue_depth == 3   # window not elapsed
    fe.offer(QUERIES[5], at=5.0)      # deadline 0.0+1.0 fires on the way
    assert len(fe.flushes) == 1 and fe.flushes[0].n_queries == 3
    assert fe.queue_depth == 1
    fe.drain()
    assert fe.queue_depth == 0 and len(fe.flushes) == 2
    assert len(fe.latencies) == 4 and not fe.failed
    for tk in fe.completed.values():
        _assert_ticket_matches(tk, _oracle_rows(served_store, tk.query))
    # the first arrival waited the full window before its flush even began
    first = server.tickets[0]
    assert fe.latencies[first.ticket_id] >= 1.0
    assert all(v >= 0.0 for v in fe.latencies.values())


def test_frontend_batch_full_trigger(served_store):
    """A compatible batch filling to max_batch fires immediately — no
    window wait — while the infinite-window baseline never self-fires."""
    server = js.HailServer(served_store,
                           js.ServerConfig(max_batch=2,
                                           result_cache=False))
    fe = js.ServerFrontend(server, js.FlushPolicy(window_s=100.0))
    fe.offer(QUERIES[0], at=0.0)
    assert fe.flushes == []
    fe.offer(QUERIES[2], at=0.0)      # same (col, projection): batch full
    assert len(fe.flushes) == 1 and fe.queue_depth == 0
    assert fe.flushes[0].n_queries == 2

    baseline = js.ServerFrontend(
        js.HailServer(served_store,
                      js.ServerConfig(max_batch=2, result_cache=False)),
        js.FlushPolicy(window_s=float("inf")))
    for i in range(4):
        baseline.offer(QUERIES[i], at=0.0)
    assert baseline.flushes == []     # inf window: drain-driven only
    baseline.drain()
    assert len(baseline.flushes) == 1
    assert baseline.flushes[0].n_queries == 4


def test_frontend_weighted_fair_admission(served_store):
    """Under overload (one batch per cycle), per-tenant WFQ weights decide
    the drain order: a weight-4 tenant gets ~4 of every 5 batch slots."""
    server = js.HailServer(served_store,
                           js.ServerConfig(max_batch=2, max_pending_total=64,
                                           result_cache=False))
    fe = js.ServerFrontend(server, js.FlushPolicy(
        window_s=float("inf"), max_batches_per_flush=1,
        weights={"A": 4.0, "B": 1.0}))
    qa = q.HailQuery(filter=("visitDate", 7000, 9000),
                     projection=("sourceIP",))
    qb = q.HailQuery(filter=("visitDate", 7000, 9000),
                     projection=("adRevenue",))   # distinct group per tenant
    for _ in range(3):
        fe.offer(qa, tenant="A", at=0.0)
        fe.offer(qb, tenant="B", at=0.0)
        fe.offer(qa, tenant="A", at=0.0)
        fe.offer(qb, tenant="B", at=0.0)
    assert fe.flushes == []           # inf window: nothing self-fires
    fe.drain()
    assert len(fe.flushes) == 6       # 6 batches of 2, one per cycle
    # reconstruct the per-cycle tenant from the server's submission order
    order, pos = [], 0
    for fl in fe.flushes:
        order.append(server.tickets[pos].tenant)
        pos += fl.n_queries
    # A/B vtimes: A's 2-query batch costs 2/4=0.5, B's costs 2/1=2.0, so
    # A drains its 3 batches in cycles 1/3/4 and B trails with 2 at the end
    assert order == ["A", "B", "A", "A", "B", "B"]
    # every answer is still exact, and later cycles queued behind earlier
    for tk in fe.completed.values():
        _assert_ticket_matches(tk, _oracle_rows(served_store, tk.query))
    assert fe.percentile_latency(99) >= fe.percentile_latency(50)


# ---------------------------------------------------------------------------
# Flight-recorder satellites: latency bookkeeping cross-checks (ISSUE 9)
# ---------------------------------------------------------------------------


def test_percentile_latency_nearest_rank_small_n():
    """Pinned nearest-rank semantics: every percentile is an actually
    observed sample — never interpolated — so small-N guards are exact."""
    fe = js.ServerFrontend.__new__(js.ServerFrontend)
    fe.latencies = {0: 0.3, 1: 0.1, 2: 0.2, 3: 0.4}
    assert fe.percentile_latency(25) == 0.1    # ceil(.25*4) = 1st smallest
    assert fe.percentile_latency(50) == 0.2    # ceil(.50*4) = 2nd
    assert fe.percentile_latency(51) == 0.3    # ceil(.51*4) = 3rd
    assert fe.percentile_latency(99) == 0.4    # ceil(.99*4) = the max
    assert fe.percentile_latency(100) == 0.4
    fe.latencies = {7: 1.5}                    # N=1: everything is the one
    assert fe.percentile_latency(1) == fe.percentile_latency(99) == 1.5
    fe.latencies = {}
    with pytest.raises(ValueError):
        fe.percentile_latency(50)


def test_percentile_latency_doctest_runs():
    import doctest
    results = doctest.DocTestRunner().run(
        doctest.DocTestFinder().find(js.ServerFrontend.percentile_latency,
                                     globs={"ServerFrontend":
                                            js.ServerFrontend})[0])
    assert results.attempted >= 3 and results.failed == 0


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 2**31 - 1),              # data + workload seed
       st.integers(2, 4))                      # queries per flush
def test_query_done_vs_modeled_completion_consistency(seed, n_q):
    """``FlushStats.query_done_s`` (measured stream-back offsets, keyed by
    ticket id) vs ``ScheduleResult.query_completion_s`` (modeled, keyed by
    the query ids the scheduler tasks carry) on randomized flushes with
    repeats (result-cache hits), adaptive commits and a demotion:

    * every done ticket streams back exactly once, within the flush wall;
    * the modeled side covers exactly the carried ids — a subset of the
      done tickets (no phantom/stale ids), each completing in
      ``(0, makespan]``;
    * a done ticket carried by NO task was answered without a scan
      (result tier, or pruned everywhere) and so completes at offset 0.
    """
    schema, eager, lazy = _make_store_pair(seed)
    cfg = mr.AdaptiveConfig(offer_rate=0.5)
    server = js.HailServer(lazy, js.ServerConfig(max_batch=4, adaptive=cfg))
    cm = server.config.cluster
    rng = np.random.default_rng(seed ^ 0xd21f7)
    history: list[tuple] = []
    verified = 0
    for step in range(4):
        for _ in range(n_q):
            if history and rng.random() < 0.5:   # repeat: result-tier path
                flt = history[int(rng.integers(0, len(history)))]
            else:
                lo, hi = sorted(rng.integers(0, VMAX, 2).tolist())
                flt = (("c0", "c1")[step % 2], int(lo), int(hi))
            history.append(flt)
            server.submit(q.HailQuery(filter=flt, projection=("c2",)))
        if step == 2:                            # race a demotion in
            keyed = [i for i, r in enumerate(lazy.replicas)
                     if r.sort_key is not None and r.indexed.any()]
            if keyed:
                lazy.demote_replica(keyed[0])
        fl = server.flush()
        new = server.tickets[verified:]
        verified = len(server.tickets)

        done = {t.ticket_id for t in new if t.status == "done"}
        assert set(fl.query_done_s) == done
        assert all(0.0 <= v <= fl.wall_s + 1e-6
                   for v in fl.query_done_s.values())

        tasks = js.flush_tasks(fl)
        sched = run_schedule(tasks,
                             SimulatedCluster(n_nodes=cm.n_nodes,
                                              map_slots=cm.map_slots),
                             spec_factor=None)
        carried = {qid for task in tasks for qid in task.query_ids}
        assert set(sched.query_completion_s) == carried
        assert carried <= done
        for qid, c in sched.query_completion_s.items():
            assert 0.0 < c <= sched.makespan_s + 1e-9
        for t in new:
            if t.status == "done" and t.ticket_id not in carried:
                assert t.result.from_cache or t.result.n_rows == 0
                assert t.explain().completion_s == 0.0
