"""Model substrate: attention equivalences, SSM chunked-vs-recurrent,
MoE invariants, losses."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import AttnCfg, Mamba1Cfg, Mamba2Cfg, MoECfg
from repro.dist.sharding import init_params
from repro.models import attention as at
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.common import apply_rope, default_positions
from repro.models.losses import chunked_xent, xent

KEY = jax.random.PRNGKey(0)
B, T, D = 2, 64, 32


def _attn_params(cfg, d=D):
    return init_params(at.attn_specs(cfg, d), KEY)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def test_chunked_equals_full_attention():
    cfg = AttnCfg(n_heads=4, n_kv=2, head_dim=16)
    p = _attn_params(cfg)
    x = jax.random.normal(KEY, (B, 256, D), jnp.float32)
    pos = default_positions(B, 256)
    q, k, v = at._project(p, x, cfg, pos)
    full = at._sdpa_full(q, k, v, pos, pos, cfg)
    chunked = at._sdpa_chunked(q, k, v, pos, pos, cfg, chunk=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5, rtol=1e-5)


def test_sliding_window_masks_older_keys():
    cfg = AttnCfg(n_heads=2, n_kv=2, head_dim=16, window=8)
    p = _attn_params(cfg)
    x = jax.random.normal(KEY, (1, 32, D), jnp.float32)
    pos = default_positions(1, 32)
    out, _ = at.attention(p, x, cfg, positions=pos, mode="train", cache=None)
    # perturbing a key beyond the window must not change the last query's out
    x2 = x.at[0, 0].add(10.0)
    out2, _ = at.attention(p, x2, cfg, positions=pos, mode="train", cache=None)
    np.testing.assert_allclose(np.asarray(out[0, -1]), np.asarray(out2[0, -1]),
                               atol=1e-5)
    # ...but with full attention it does
    cfg_f = AttnCfg(n_heads=2, n_kv=2, head_dim=16)
    p2 = _attn_params(cfg_f)
    o1, _ = at.attention(p2, x, cfg_f, positions=pos, mode="train", cache=None)
    o2, _ = at.attention(p2, x2, cfg_f, positions=pos, mode="train", cache=None)
    assert np.abs(np.asarray(o1[0, -1]) - np.asarray(o2[0, -1])).max() > 1e-4


def test_banded_equals_full_sliding_window():
    """The §Perf banded SWA path must be bit-compatible with masked full
    attention (it is exact, not an approximation)."""
    cfg = AttnCfg(n_heads=4, n_kv=2, head_dim=16, window=32)
    p = _attn_params(cfg)
    x = jax.random.normal(KEY, (2, 128, D), jnp.float32)
    pos = default_positions(2, 128)
    q, k, v = at._project(p, x, cfg, pos)
    full = at._sdpa_full(q, k, v, pos, pos, cfg)
    band = at._sdpa_banded(q, k, v, pos, pos, cfg)
    np.testing.assert_allclose(np.asarray(full), np.asarray(band),
                               atol=1e-5, rtol=1e-5)


def test_ring_cache_decode_matches_full_cache():
    """Windowed decode with a ring cache == windowed decode with full cache."""
    cfg = AttnCfg(n_heads=2, n_kv=2, head_dim=16, window=8)
    p = _attn_params(cfg)
    xs = jax.random.normal(KEY, (1, 24, D), jnp.float32)
    pos = default_positions(1, 16)
    # prefill 16 tokens -> ring cache of 8
    _, ring = at.attention(p, xs[:, :16], cfg, positions=pos, mode="prefill",
                           cache=None)
    assert ring["k"].shape[1] == 8
    # full-length cache built by hand (window masking via positions)
    cfg_full = dataclasses.replace(cfg)
    _, full = at.attention(
        p, xs[:, :16],
        dataclasses.replace(cfg, window=None), positions=pos,
        mode="prefill", cache=None)
    for t in range(16, 24):
        ptok = jnp.full((1, 1), t, jnp.int32)
        o_ring, ring = at.attention(p, xs[:, t:t + 1], cfg, positions=ptok,
                                    mode="decode", cache=ring)
        o_full, full = at.attention(p, xs[:, t:t + 1], cfg, positions=ptok,
                                    mode="decode", cache=full)
        np.testing.assert_allclose(np.asarray(o_ring), np.asarray(o_full),
                                   atol=1e-5, rtol=1e-4)


def test_mrope_sections_rotate_independently():
    x = jax.random.normal(KEY, (1, 8, 2, 16), jnp.float32)
    pos = default_positions(1, 8, mrope=True)
    a = apply_rope(x, pos, 10000.0, mrope_section=(2, 3, 3))
    b = apply_rope(x, pos[0], 10000.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # diverging h/w streams change only their sections
    pos2 = pos.at[1].add(5)
    c = apply_rope(x, pos2, 10000.0, mrope_section=(2, 3, 3))
    assert np.abs(np.asarray(c) - np.asarray(a)).max() > 1e-4
    np.testing.assert_allclose(np.asarray(c[..., :2]), np.asarray(a[..., :2]),
                               atol=1e-6)  # t-section untouched


# ---------------------------------------------------------------------------
# Mamba: chunked scan == step-by-step recurrence (decode path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind,cfg", [
    ("mamba1", Mamba1Cfg(d_inner=32, d_state=8, dt_rank=8, chunk=8)),
    ("mamba2", Mamba2Cfg(d_inner=32, d_state=8, head_dim=8, chunk=8)),
])
def test_mamba_chunked_matches_recurrence(kind, cfg):
    d_model = 16
    t = 32
    fn = mb.mamba1 if kind == "mamba1" else mb.mamba2
    specs = (mb.mamba1_specs if kind == "mamba1" else mb.mamba2_specs)(cfg, d_model)
    cspecs = (mb.mamba1_cache_specs if kind == "mamba1"
              else mb.mamba2_cache_specs)(cfg, d_model, 1, jnp.float32)
    p = init_params(specs, KEY)
    x = jax.random.normal(KEY, (1, t, d_model), jnp.float32) * 0.5
    y_train, _ = fn(p, x, cfg, mode="train", cache=None)
    cache = init_params(cspecs, KEY)  # zeros
    ys = []
    for i in range(t):
        y, cache = fn(p, x[:, i:i + 1], cfg, mode="decode", cache=cache)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               atol=2e-4, rtol=2e-3)


def test_mamba_prefill_state_continues_decode():
    cfg = Mamba1Cfg(d_inner=32, d_state=8, dt_rank=8, chunk=8)
    p = init_params(mb.mamba1_specs(cfg, 16), KEY)
    x = jax.random.normal(KEY, (1, 24, 16), jnp.float32) * 0.5
    # full pass over 24
    y_all, _ = fn_out = mb.mamba1(p, x, cfg, mode="train", cache=None)
    # prefill 16 then decode 8
    _, cache = mb.mamba1(p, x[:, :16], cfg, mode="prefill", cache=None)
    ys = []
    for i in range(16, 24):
        y, cache = mb.mamba1(p, x[:, i:i + 1], cfg, mode="decode", cache=cache)
        ys.append(y)
    got = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_all[:, 16:]), np.asarray(got),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


def _moe(cfg, d=16):
    return init_params(moe_mod.moe_specs(cfg, d), KEY)


def test_moe_conservation_and_gates():
    cfg = MoECfg(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0)
    p = _moe(cfg)
    x = jax.random.normal(KEY, (2, 16, 16), jnp.float32)
    y, aux = moe_mod.moe(p, x, cfg, return_aux=True)
    assert float(aux["kept_fraction"]) == 1.0        # huge capacity: no drops
    idx = np.asarray(aux["top_idx"])
    assert (idx[:, 0] != idx[:, 1]).all()            # distinct experts
    g = np.asarray(aux["gates"])
    np.testing.assert_allclose(g.sum(-1), 1.0, atol=1e-5)


def test_moe_matches_dense_oracle():
    """With no drops, scatter-dispatch == per-token dense mixture."""
    cfg = MoECfg(n_experts=4, top_k=2, d_ff=8, capacity_factor=16.0)
    d = 8
    p = _moe(cfg, d)
    x = jax.random.normal(KEY, (1, 8, d), jnp.float32)
    y = moe_mod.moe(p, x, cfg)
    logits = np.asarray(jnp.einsum("btd,de->bte", x, p["router"]))[0]
    xf = np.asarray(x)[0]
    wg, wu, wd = (np.asarray(p[k]) for k in ("w_gate", "w_up", "w_down"))
    want = np.zeros_like(xf)
    for t in range(8):
        top = np.argsort(-logits[t])[:2]
        gate = np.exp(logits[t][top] - logits[t][top].max())
        gate = gate / gate.sum()
        for gi, e in zip(gate, top):
            h = (xf[t] @ wg[e])
            h = h / (1 + np.exp(-h)) * (xf[t] @ wu[e])
            want[t] += gi * (h @ wd[e])
    np.testing.assert_allclose(np.asarray(y)[0], want, atol=1e-4, rtol=1e-3)


def test_load_balance_loss_minimized_at_uniform():
    e = 8
    # perfectly uniform router + uniform routing -> loss == 1
    lg = jnp.zeros((2, 16, e))
    ti = jnp.stack([jnp.arange(16) % e, (jnp.arange(16) + 1) % e],
                   -1)[None].repeat(2, 0)
    uniform = float(moe_mod.load_balance_loss(lg, ti))
    assert abs(uniform - 1.0) < 1e-5
    # collapsed routing -> loss >> 1
    ti_bad = jnp.zeros((2, 16, 2), jnp.int32)
    lg_bad = jnp.zeros((2, 16, e)).at[..., 0].set(5.0)
    collapsed = float(moe_mod.load_balance_loss(lg_bad, ti_bad))
    assert collapsed > 3.0


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.25, 2.0))
def test_moe_capacity_drops_bounded(seed, cf):
    cfg = MoECfg(n_experts=4, top_k=2, d_ff=8, capacity_factor=cf)
    p = _moe(cfg, 8)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 32, 8), jnp.float32)
    y, aux = moe_mod.moe(p, x, cfg, return_aux=True)
    kept = float(aux["kept_fraction"])
    assert 0.0 < kept <= 1.0
    cap = moe_mod.capacity(cfg, 32)
    pos = np.asarray(aux["pos"])
    kmask = pos < cap
    assert kept == pytest.approx(kmask.mean(), abs=1e-6)
    assert np.isfinite(np.asarray(y)).all()


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def test_chunked_xent_matches_plain():
    v, d = 64, 16
    x = jax.random.normal(KEY, (2, 32, d), jnp.float32)
    head = jax.random.normal(jax.random.fold_in(KEY, 1), (d, v), jnp.float32)
    labels = jax.random.randint(KEY, (2, 32), 0, v)
    logits = jnp.einsum("btd,dv->btv", x, head)
    a = xent(logits, labels)
    b = chunked_xent(x, head, labels, n_chunks=4)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)
