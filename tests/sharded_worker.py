"""Subprocess worker for the 8-device sharded-scan property test.

Forced host device count MUST be set before any jax import (conftest forbids
XLA_FLAGS in the test process itself, so this runs via subprocess).  The
worker randomizes interleaved commits, demotions, quarantines and
re-replications between sharded flushes and checks every answered row-set
against the uncached oracle computed from the generating columns.  Exits
non-zero (assertion) on any divergence; prints PASS lines the test asserts.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import math  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import mapreduce as mr  # noqa: E402
from repro.core import query as q  # noqa: E402
from repro.core import schema as sc  # noqa: E402
from repro.core import upload as up  # noqa: E402
from repro.core.parse import format_rows, parse_block  # noqa: E402
from repro.core.schema import ROWID  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.runtime.jobserver import HailServer, ServerConfig  # noqa: E402

ROWS, BLOCKS, PART, NODES = 256, 12, 64, 6
N_DEV = 8


def build_store():
    cols = sc.gen_uservisits(ROWS * BLOCKS, seed=3)
    raw = format_rows(sc.USERVISITS, cols, bad_fraction=0.004)
    store, _ = up.hail_upload(
        sc.USERVISITS, raw.reshape(BLOCKS, ROWS, -1),
        ["visitDate", "sourceIP"], partition_size=PART, n_nodes=NODES)
    import jax
    bad = np.asarray(jax.jit(jax.vmap(
        lambda r: parse_block(sc.USERVISITS, r)[1]))(
            raw.reshape(BLOCKS, ROWS, -1))).reshape(-1)
    return store, cols, bad


def oracle_rowids(cols, bad, col, lo, hi):
    keep = (cols[col] >= lo) & (cols[col] <= hi) & ~bad
    return np.nonzero(keep)[0]


def main():
    import jax
    assert jax.device_count() == N_DEV, jax.device_count()
    mesh = make_mesh((N_DEV,), ("data",))
    store, cols, bad = build_store()
    rng = np.random.default_rng(0)
    qcols = ["visitDate", "sourceIP", "adRevenue"]

    # --- dispatch-count model: per-device fused dispatches = ceil(S/D) ----
    query = q.HailQuery(filter=("visitDate", 7305, 7670),
                        projection=("sourceIP",))
    with ops.stats_scope() as stats:
        job = mr.run_job(store, query, mesh=mesh)
    s = len(job.split_s)
    waves = stats.dispatches["hail_read_sharded_waves"]
    assert waves == math.ceil(s / N_DEV), (waves, s)
    assert stats.dispatches["hail_read_sharded_splits"] == s
    serial = mr.run_job(store, query)
    assert job.results["n_rows"] == serial.results["n_rows"]
    assert job.bytes_read == serial.bytes_read, \
        (job.bytes_read, serial.bytes_read)
    print(f"PASS dispatch-model waves={waves} splits={s}")

    # --- randomized interleaving: flushes vs the uncached oracle ----------
    server = HailServer(store, ServerConfig(
        mesh=mesh, result_cache=False,
        adaptive=mr.AdaptiveConfig(offer_rate=0.5)))
    checked = 0
    for round_i in range(6):
        # mutate: quarantine a random healthy copy / demote / re-replicate
        op = rng.integers(0, 4)
        if op == 0:
            live = store.live_replica_ids()
            rid = int(rng.choice(live))
            b = int(rng.integers(0, store.n_blocks))
            if len(store.alive_replica_ids(b)) > 1 and \
                    not store.is_quarantined(rid, b):
                store.quarantine_block(rid, b)
        elif op == 1:
            claimed = [i for i in store.live_replica_ids()
                       if store.replicas[i].sort_key is not None]
            if len(claimed) > 1:
                store.demote_replica(int(rng.choice(claimed)))
        elif op == 2 and len(store.live_replica_ids()) < 4:
            store.add_replica()
        elif op == 3 and len(store.live_replica_ids()) > 2:
            rid = store.live_replica_ids()[-1]
            try:
                store.decommission_replica(rid)
            except ValueError:
                pass                 # a block's last healthy copy: keep it
        # submit a compatible batch + a singleton on another column
        col = qcols[int(rng.integers(0, len(qcols)))]
        vals = np.sort(cols[col])
        tickets = []
        for _ in range(3):
            lo, hi = sorted(int(vals[i]) for i in
                            rng.integers(0, len(vals), size=2))
            tk = server.submit(q.HailQuery(filter=(col, lo, hi),
                                           projection=("adRevenue",)))
            tickets.append((tk, col, lo, hi))
        fail_at = 0.5 if round_i == 3 else None    # mid-flush failover
        server.flush(fail_node_at=fail_at)
        for tk, tcol, lo, hi in tickets:
            assert tk.status == "done", tk.error
            got = np.sort(tk.result.rows[ROWID])
            want = oracle_rowids(cols, bad, tcol, lo, hi)
            assert got.shape == want.shape and (got == want).all(), \
                (round_i, tcol, lo, hi, got.shape, want.shape)
            checked += 1
    print(f"PASS oracle-equality queries={checked}")


if __name__ == "__main__":
    main()
