"""Sharded flush scans: wave reader equality on one device (shard_map over a
size-1 axis, in-process) and the full 8-device property test (subprocess —
conftest forbids XLA_FLAGS in this process, and the forced host device count
must be set before any jax import)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import query as q
from repro.core.splitting import hail_splits
from repro.launch.mesh import make_mesh

Q1 = q.HailQuery(filter=("visitDate", 7305, 7670), projection=("sourceIP",))
Q2 = q.HailQuery(filter=("visitDate", 7400, 7500), projection=("sourceIP",))


def test_wave_reader_matches_batch_reader(hail_store):
    """read_hail_batch_sharded over a size-1 'data' axis must reproduce the
    unsharded fused batch reader split by split: same masks, same projected
    values under the mask, same bytes accounting."""
    mesh = make_mesh((1,), ("data",))
    queries = [Q1, Q2]
    qplan = q.plan(hail_store, Q1)
    splits = hail_splits(hail_store, qplan, 4)
    assert len(splits) >= 2
    for sp in splits:
        ids = list(sp.block_ids)
        gathered = q.gather_shared_scan_inputs(hail_store, queries, qplan,
                                               ids)
        [(sharded, sh_bytes)] = q.read_hail_batch_sharded(
            hail_store, queries, [gathered], mesh, ("data",))
        serial, se_bytes = q.read_hail_batch(hail_store, queries, qplan, ids)
        assert float(sh_bytes) == float(se_bytes)
        for rs, rb in zip(sharded, serial):
            ms, mb = np.asarray(rs.mask), np.asarray(rb.mask)
            np.testing.assert_array_equal(ms, mb)
            assert float(rs.bytes_read) == float(rb.bytes_read)
            for c in rs.cols:
                np.testing.assert_array_equal(
                    np.asarray(rs.cols[c])[mb], np.asarray(rb.cols[c])[mb])


def test_wave_reader_pads_ragged_wave(hail_store):
    """A wave whose splits have different block counts pads with DEAD blocks;
    padded rows must contribute no matches and no bytes."""
    mesh = make_mesh((1,), ("data",))
    qplan = q.plan(hail_store, Q1)
    ids = [0, 2]                      # 2-block split alone in the wave
    gathered = q.gather_shared_scan_inputs(hail_store, [Q1], qplan, ids)
    [(sharded, _)] = q.read_hail_batch_sharded(hail_store, [Q1], [gathered],
                                               mesh, ("data",))
    serial, _ = q.read_hail_batch(hail_store, [Q1], qplan, ids)
    np.testing.assert_array_equal(np.asarray(sharded[0].mask),
                                  np.asarray(serial[0].mask))


def test_run_job_falls_back_without_scan_axis(hail_store):
    """A (1, 1) host mesh has no multi-device scan axis: run_job must take
    the serial path and produce identical stats shape."""
    from repro.core import mapreduce as mr
    from repro.launch.mesh import make_host_mesh
    base = mr.run_job(hail_store, Q1)
    via_mesh = mr.run_job(hail_store, Q1, mesh=make_host_mesh())
    assert via_mesh.results["n_rows"] == base.results["n_rows"]
    assert via_mesh.n_tasks == base.n_tasks


def test_sharded_flush_property_8dev():
    """Randomized 8-device property test: sharded flush row-sets equal the
    uncached oracle across interleaved commits, demotions, quarantines,
    re-replications and a mid-flush failover; per-device fused dispatches
    follow the ceil(splits / n_dev) model."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)        # the worker sets its own, pre-import
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "sharded_worker.py")],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "PASS dispatch-model" in proc.stdout
    assert "PASS oracle-equality" in proc.stdout
