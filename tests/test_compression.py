"""bf16 gradient all-reduce with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compression import compressed_mean_grads, init_residual
from repro.launch.mesh import make_mesh


def test_exact_for_bf16_representable():
    mesh = make_mesh((1,), ("data",))
    g = {"w": jnp.asarray([1.0, 0.5, -2.0, 0.25])}
    r = init_residual(g)
    m, r2 = compressed_mean_grads(mesh, g, r)
    np.testing.assert_array_equal(np.asarray(m["w"]), np.asarray(g["w"]))
    np.testing.assert_array_equal(np.asarray(r2["w"]), np.zeros(4))


def test_error_feedback_preserves_mean():
    """Quantization error must be carried, not lost: summed updates over
    many steps converge to the true sum."""
    mesh = make_mesh((1,), ("data",))
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=256).astype(np.float32)) * 1e-3
    r = init_residual({"w": g_true})
    acc = np.zeros(256, np.float64)
    for _ in range(64):
        m, r = compressed_mean_grads(mesh, {"w": g_true}, r)
        acc += np.asarray(m["w"], np.float64)
    want = np.asarray(g_true, np.float64) * 64
    # with error feedback the accumulated drift is bounded by one ulp of the
    # LAST step, not 64 of them
    err_fb = np.abs(acc - want).max()
    naive = np.abs(
        np.asarray(g_true.astype(jnp.bfloat16).astype(jnp.float32), np.float64)
        * 64 - want).max()
    assert err_fb <= naive + 1e-12
    assert err_fb < 1e-4


def test_residual_absorbs_quantization_error():
    mesh = make_mesh((1,), ("data",))
    g = {"w": jnp.asarray([1e-4, 3.14159, -1e-5])}
    r = init_residual(g)
    m, r2 = compressed_mean_grads(mesh, g, r)
    np.testing.assert_allclose(np.asarray(m["w"]) + np.asarray(r2["w"]),
                               np.asarray(g["w"]), rtol=1e-7)
