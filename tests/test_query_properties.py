"""Property-test harness over the WHOLE query pipeline: for random schemas,
block counts, replica layouts, bad-record rates and (lo, hi) ranges, the
three record readers — batched jnp (``read_hail``), fused Pallas
(``read_hail_kernels``) and the Hadoop parse+scan baseline
(``read_hadoop``) — must agree on the qualifying row-set, and adaptive
convergence must preserve it.

Shapes are drawn from a small pool so jit caches amortize across examples
(interpret-mode kernels retrace per shape).
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import governor as gv
from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows
from repro.core.schema import ROWID

ROWS, PART = 256, 64
VMAX = 1 << 20


def _make_schema(n_cols: int) -> sc.Schema:
    return sc.Schema(f"prop{n_cols}",
                     tuple(sc.Column(f"c{i}") for i in range(n_cols)))


def _make_raw(schema: sc.Schema, blocks: int, seed: int, bad_fraction: float):
    r = np.random.default_rng(seed)
    cols = {c.name: r.integers(0, VMAX, ROWS * blocks, dtype=np.int32)
            for c in schema.columns}
    raw = format_rows(schema, cols, bad_fraction=bad_fraction, seed=seed + 1)
    return cols, raw.reshape(blocks, ROWS, -1)


def _rowset(res):
    rows = q.collect(res)
    order = np.argsort(rows[ROWID])
    return {k: v[order] for k, v in rows.items()}


def _assert_same(a, b, keys):
    for k in (*keys, ROWID):
        np.testing.assert_array_equal(a[k], b[k])


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 4),                       # schema width
       st.integers(1, 3),                       # block count
       st.integers(2, 3),                       # replication
       st.integers(0, 2**31 - 1),               # data seed / layout seed
       st.tuples(st.integers(0, VMAX), st.integers(0, VMAX)),  # range
       st.sampled_from([0.0, 0.02]))            # bad-record rate
def test_readers_agree_on_any_pipeline(n_cols, blocks, replication, seed,
                                       lohi, bad_fraction):
    schema = _make_schema(n_cols)
    cols, raw = _make_raw(schema, blocks, seed, bad_fraction)
    lo, hi = min(lohi), max(lohi)
    names = schema.names
    filter_col = names[seed % n_cols]
    # random replica layout: one replica indexed on the filter column or
    # not at all (forces the full-scan path), others rotate/unindexed
    keys = [filter_col if seed % 3 else None]
    keys += [names[(seed + i) % n_cols] if (seed + i) % 2 else None
             for i in range(1, replication)]
    proj = (names[-1],)
    hail, _ = up.hail_upload(schema, raw, keys, partition_size=PART,
                             n_nodes=4)
    hdfs, _ = up.hdfs_upload(schema, raw, replication=replication, n_nodes=4)
    query = q.HailQuery(filter=(filter_col, lo, hi), projection=proj)
    qp = q.plan(hail, query)
    a = _rowset(q.read_hail(hail, query, qp))
    b = _rowset(q.read_hail_kernels(hail, query, qp))
    c = _rowset(q.read_hadoop(hdfs, query))
    _assert_same(a, b, proj)
    _assert_same(a, c, proj)
    # spot-check against the generator oracle on good rows (bad rows were
    # corrupted post-encode, so membership is parser-defined)
    if bad_fraction == 0.0:
        m = (cols[filter_col] >= lo) & (cols[filter_col] <= hi)
        np.testing.assert_array_equal(a[proj[0]], cols[proj[0]][m])


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 3),                       # block count
       st.integers(0, 2**31 - 1),               # seed
       st.tuples(st.integers(0, VMAX), st.integers(0, VMAX)),
       st.sampled_from([0.34, 0.5, 1.0]),       # offer rate
       st.booleans())                           # kernels reader for jobs
def test_adaptive_jobs_preserve_rowset(blocks, seed, lohi, offer_rate,
                                       use_kernels):
    """Lazy store + N adaptive jobs == eager store, for every intermediate
    store state; blocks_indexed is monotone and full-scan fraction hits 0."""
    schema = _make_schema(3)
    _, raw = _make_raw(schema, blocks, seed, bad_fraction=0.01)
    lo, hi = min(lohi), max(lohi)
    filter_col = schema.names[seed % 3]
    query = q.HailQuery(filter=(filter_col, lo, hi),
                        projection=(schema.names[0],))
    eager, _ = up.hail_upload(schema, raw, [filter_col, None],
                              partition_size=PART, n_nodes=4)
    lazy, _ = up.hail_upload(schema, raw, index_columns=(), replication=2,
                             partition_size=PART, n_nodes=4)
    want = _rowset(q.read_hail(eager, query, q.plan(eager, query)))
    reader = "kernels" if use_kernels else "jnp"
    seen = 0
    for _ in range(int(np.ceil(1 / offer_rate)) + 1):
        stats = mr.run_job(lazy, query, adaptive=mr.AdaptiveConfig(
            offer_rate=offer_rate), reader=reader)
        assert stats.blocks_indexed >= 0
        seen += stats.blocks_indexed
        got = _rowset(q.read_hail(lazy, query, q.plan(lazy, query)))
        _assert_same(got, want, (schema.names[0],))
    assert seen == blocks
    assert lazy.indexed_fraction(filter_col) == 1.0
    final = mr.run_job(lazy, query, reader=reader)
    assert final.full_scan_blocks == 0
    assert final.results["n_rows"] == len(want[ROWID])


@settings(max_examples=6, deadline=None)
@given(st.integers(2, 3),                       # block count
       st.integers(0, 2**31 - 1),               # seed
       st.tuples(st.integers(0, VMAX), st.integers(0, VMAX)))
def test_readers_agree_with_demoted_replica(blocks, seed, lohi):
    """The jnp == fused-kernel == Hadoop-reader equivalence oracle must also
    hold for stores holding a just-demoted replica AND a mid-re-key replica
    (partially re-indexed on the shifted workload's column)."""
    schema = _make_schema(3)
    cols, raw = _make_raw(schema, blocks, seed, bad_fraction=0.01)
    lo, hi = min(lohi), max(lohi)
    names = schema.names
    a_col, b_col = names[seed % 3], names[(seed + 1) % 3]
    hail, _ = up.hail_upload(schema, raw, index_columns=(), replication=2,
                             partition_size=PART, n_nodes=4)
    hdfs, _ = up.hdfs_upload(schema, raw, replication=2, n_nodes=4)
    gv.govern(hail, max_indexed_blocks=blocks)
    # converge on A, then ONE under-offered B job: demotes A's replica and
    # leaves B's replica mid-re-key (some blocks indexed, some not)
    qa = q.HailQuery(filter=(a_col, lo, hi), projection=(names[-1],))
    qb = q.HailQuery(filter=(b_col, lo, hi), projection=(names[-1],))
    while hail.indexed_fraction(a_col) < 1.0:
        mr.run_job(hail, qa, adaptive=mr.AdaptiveConfig(offer_rate=0.5))
    stats = mr.run_job(hail, qb,
                       adaptive=mr.AdaptiveConfig(offer_rate=1.0,
                                                  max_build_per_job=1))
    assert stats.blocks_demoted == blocks        # A evicted...
    frac_b = hail.indexed_fraction(b_col)
    assert 0.0 < frac_b < 1.0                    # ...B mid-re-key
    for query in (qa, qb):
        qp = q.plan(hail, query)
        a = _rowset(q.read_hail(hail, query, qp))
        b = _rowset(q.read_hail_kernels(hail, query, qp))
        c = _rowset(q.read_hadoop(hdfs, query))
        _assert_same(a, b, query.projection)
        _assert_same(a, c, query.projection)
