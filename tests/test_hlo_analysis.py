"""HLO analyzer: validated against XLA's own cost_analysis on scan-free
programs; trip-count multiplication validated on scanned programs; collective
accounting validated on a synthetic HLO fixture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as ha


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _xla_cost(comp) -> dict:
    ca = comp.cost_analysis()
    return ca[0] if isinstance(ca, list) else dict(ca)  # jax<=0.4 wraps in a list


def test_dot_flops_match_cost_analysis():
    a = jnp.zeros((256, 512), jnp.float32)
    b = jnp.zeros((512, 128), jnp.float32)
    comp = _compile(lambda x, y: x @ y, a, b)
    got = ha.analyze_hlo_text(comp.as_text())
    want = _xla_cost(comp)["flops"]
    assert got["dot_flops"] == pytest.approx(want, rel=0.01)
    assert got["dot_flops"] == 2 * 256 * 512 * 128


def test_scan_trip_count_correction():
    """XLA counts a scan body once; the analyzer multiplies by trip count."""
    L = 8
    w = jnp.zeros((L, 64, 64), jnp.float32)

    def f(x, w):
        def body(x, wi):
            return x @ wi, None
        x, _ = jax.lax.scan(body, x, w)
        return x

    x = jnp.zeros((32, 64), jnp.float32)
    comp = _compile(f, x, w)
    got = ha.analyze_hlo_text(comp.as_text())
    xla = _xla_cost(comp)["flops"]
    per_layer = 2 * 32 * 64 * 64
    assert got["dot_flops"] == pytest.approx(L * per_layer, rel=0.01)
    # sanity: XLA indeed undercounts (body counted ~once)
    assert xla < got["dot_flops"]


def test_elementwise_flops_counted():
    x = jnp.zeros((1000,), jnp.float32)
    comp = _compile(lambda x: jnp.tanh(x) + x * 2.0, x)
    got = ha.analyze_hlo_text(comp.as_text())
    assert got["flops"] >= 1000  # at least one op over 1000 elems survived fusion


SYNTH = """
HloModule synth

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[4,8]<=[32], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(6)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%zero, %x)
  %w = (s32[], f32[128,256]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"6"}}
  %ag = f32[256,256]{1,0} all-gather(%x), replica_groups=[16,2]<=[32], dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%x), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_collectives_and_trip_counts():
    got = ha.analyze_hlo_text(SYNTH)
    payload = 128 * 256 * 4
    # all-reduce inside while x6, group size 8
    assert got["coll_bytes"]["all-reduce"] == 6 * payload
    assert got["coll_count"]["all-reduce"] == 6
    # all-gather once: payload = max(out, in) = 256*256*4, group 2
    ag_payload = 256 * 256 * 4
    assert got["coll_bytes"]["all-gather"] == ag_payload
    assert got["coll_bytes"]["collective-permute"] == payload
    want_link = (6 * 2 * payload * 7 / 8) + ag_payload * 1 / 2 + payload
    assert got["coll_link_bytes"] == pytest.approx(want_link)


def test_roofline_terms_and_dominance():
    hw = {"peak_bf16_flops": 1e12, "hbm_bw": 1e9, "ici_bw": 1e9}
    costs = {"flops": 1e12, "hbm_bytes": 5e9, "coll_link_bytes": 1e9}
    terms = ha.roofline_terms(costs, hw)
    assert terms["compute_s"] == 1.0
    assert terms["memory_s"] == 5.0
    assert terms["collective_s"] == 1.0
    assert terms["dominant"] == "memory"
    assert terms["step_lower_bound_s"] == 5.0
