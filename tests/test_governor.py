"""Index governor: storage budgets, LRU eviction, replica re-claiming, and
workload-shift chaos.

The destructive transition under test is ``BlockStore.demote_replica`` —
every invariant the adaptive path established (row-sets vs the eager oracle,
checksums, Dir_rep coherence, bad-mask placement) must hold across index
REMOVAL and re-keying.  Property tests drive randomized schemas, budgets,
offer rates and multi-phase filter-column shifts; chaos tests race node
failure against a demotion inside one job.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import governor as gv
from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows
from repro.core.schema import ROWID

from conftest import BLOCKS, PART, ROWS

QA = q.HailQuery(filter=("visitDate", 7305, 9000), projection=("sourceIP",))
QB = q.HailQuery(filter=("sourceIP", 0, 1 << 30), projection=("visitDate",))
QC = q.HailQuery(filter=("duration", 0, 5000), projection=("destURL",))

P_ROWS, P_PART = 256, 64
VMAX = 1 << 20


@pytest.fixture()
def lazy_store(uservisits_raw):
    """FRESH unindexed store per test — governor jobs mutate it."""
    _, raw = uservisits_raw
    store, _ = up.hail_upload(sc.USERVISITS, raw, index_columns=(),
                              partition_size=PART, n_nodes=6, replication=3)
    return store


def _rowset(store, query):
    rows = q.collect(q.read_hail(store, query, q.plan(store, query)))
    order = np.argsort(rows[ROWID])
    return {k: v[order] for k, v in rows.items()}


def _assert_rows_equal(a, b, cols):
    for k in (*cols, ROWID):
        np.testing.assert_array_equal(a[k], b[k])


def _make_schema(n_cols):
    return sc.Schema(f"gov{n_cols}",
                     tuple(sc.Column(f"c{i}") for i in range(n_cols)))


def _make_raw(schema, blocks, seed, bad_fraction=0.01):
    r = np.random.default_rng(seed)
    cols = {c.name: r.integers(0, VMAX, P_ROWS * blocks, dtype=np.int32)
            for c in schema.columns}
    raw = format_rows(schema, cols, bad_fraction=bad_fraction, seed=seed + 1)
    return cols, raw.reshape(blocks, P_ROWS, -1)


# ---------------------------------------------------------------------------
# The acceptance scenario: two-phase workload shift under a one-replica
# budget — converge on A, budget forces demotion when B arrives, reconverge
# on B, row-sets match the eager unbudgeted oracle at EVERY job.
# ---------------------------------------------------------------------------


def test_two_phase_workload_shift(lazy_store, hail_store):
    gov = gv.govern(lazy_store, max_indexed_blocks=BLOCKS)
    cfg = mr.AdaptiveConfig(offer_rate=0.5)
    jobs = math.ceil(1 / cfg.offer_rate) + 1
    want_a = _rowset(hail_store, QA)
    for _ in range(jobs):
        stats = mr.run_job(lazy_store, QA, adaptive=cfg)
        _assert_rows_equal(_rowset(lazy_store, QA), want_a, QA.projection)
        assert stats.results["n_rows"] == len(want_a[ROWID])
        assert lazy_store.total_indexed_blocks() <= BLOCKS
        assert stats.blocks_demoted == 0          # phase A fits the budget
    assert lazy_store.indexed_fraction("visitDate") == 1.0

    # phase B: the budget is full — the first B job must evict A's replica
    # (LRU victim), re-claim it... and keep every row-set exact meanwhile
    want_b = _rowset(hail_store, QB)
    demoted, fracs_b = [], []
    for _ in range(jobs):
        stats = mr.run_job(lazy_store, QB, adaptive=cfg)
        _assert_rows_equal(_rowset(lazy_store, QB), want_b, QB.projection)
        assert stats.results["n_rows"] == len(want_b[ROWID])
        assert lazy_store.total_indexed_blocks() <= BLOCKS
        demoted.append(stats.blocks_demoted)
        fracs_b.append(lazy_store.indexed_fraction("sourceIP"))
        # demotion wall is measured and charged per split, like builds
        assert stats.rekey_s == pytest.approx(sum(stats.demote_s))
        assert len(stats.demote_s) == len(stats.split_s)
        if stats.blocks_demoted:
            assert stats.rekey_s > 0
    assert demoted[0] == BLOCKS and sum(demoted[1:]) == 0
    assert fracs_b == sorted(fracs_b) and fracs_b[-1] == 1.0
    # A's index is gone; its replica was re-claimed for B
    assert lazy_store.indexed_fraction("visitDate") == 0.0
    assert gov.blocks_demoted_total == BLOCKS
    # ...and A still answers correctly (full scan over the demoted replica)
    _assert_rows_equal(_rowset(lazy_store, QA), want_a, QA.projection)


def test_reclaim_when_all_replicas_claimed(lazy_store):
    """Job-start demotion path: every replica claimed by other keys and the
    budget is NOT the constraint — a shifted workload must still be able to
    re-claim the LRU replica, but only once the claim-time HYSTERESIS is
    satisfied (>= 2 distinct jobs of misses, the requesting job included):
    a workload that queries once never destroys a warm index."""
    gov = gv.govern(lazy_store, max_indexed_blocks=10 * BLOCKS)
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    mr.run_job(lazy_store, QA, adaptive=cfg)
    mr.run_job(lazy_store, QB, adaptive=cfg)
    # claim the third replica too so QC finds nothing unclaimed
    mr.run_job(lazy_store, QC, adaptive=cfg)
    assert all(r.sort_key is not None for r in lazy_store.replicas)
    # keep B and C warm so A is the LRU column when a 4th workload arrives
    mr.run_job(lazy_store, QB)
    mr.run_job(lazy_store, QC)
    q4 = q.HailQuery(filter=("adRevenue", 0, 50_000),
                     projection=("sourceIP",))
    # FIRST adRevenue job ever: hysteresis blocks the claim-time demotion —
    # the one-off query full-scans and every warm index survives
    assert not gov.may_reclaim(lazy_store, "adRevenue")
    stats = mr.run_job(lazy_store, q4, adaptive=cfg)
    assert stats.blocks_demoted == 0 and stats.blocks_indexed == 0
    assert lazy_store.indexed_fraction("visitDate") == 1.0
    # the workload comes back: its second distinct job of misses crosses
    # the hysteresis threshold and re-claims the LRU replica (the probe
    # advances the job clock like run_job does — prior jobs' misses count,
    # the requesting job's own don't)
    gv.note_job_start(lazy_store)
    assert gov.may_reclaim(lazy_store, "adRevenue")
    stats = mr.run_job(lazy_store, q4, adaptive=cfg)
    assert stats.blocks_demoted == BLOCKS
    assert lazy_store.indexed_fraction("visitDate") == 0.0   # LRU evicted
    assert lazy_store.indexed_fraction("sourceIP") == 1.0    # warm survives
    assert lazy_store.indexed_fraction("duration") == 1.0
    assert lazy_store.indexed_fraction("adRevenue") == 1.0


# ---------------------------------------------------------------------------
# Property tests: randomized schemas, budgets, offer rates, phase sequences
# ---------------------------------------------------------------------------


@settings(max_examples=5, deadline=None)
@given(st.integers(3, 4),                        # schema width
       st.integers(2, 3),                        # block count
       st.integers(2, 3),                        # replication
       st.sampled_from(["full", "double", "tight"]),   # budget regime
       st.sampled_from([0.5, 1.0]),              # offer rate
       st.integers(0, 2**31 - 1))                # data / phase seed
def test_workload_shift_property(n_cols, blocks, replication, budget_kind,
                                 offer_rate, seed):
    """For any store shape, budget and 2-3 phase filter-column sequence:
    row-sets stay identical to an unbudgeted eager store at every job,
    ``indexed_fraction`` reconverges to min(1, budget/blocks) after each
    shift, and the total indexed blocks never exceed the budget."""
    schema = _make_schema(n_cols)
    _, raw = _make_raw(schema, blocks, seed)
    names = schema.names
    n_phases = 2 + seed % 2
    cols = [names[(seed + i) % n_cols] for i in range(n_phases)]
    assert len(set(cols)) == len(cols)           # consecutive phases differ
    budget = {"full": blocks, "double": 2 * blocks,
              "tight": max(1, blocks - 1)}[budget_kind]
    eager, _ = up.hail_upload(schema, raw, list(dict.fromkeys(cols)),
                              partition_size=P_PART, n_nodes=4)
    lazy, _ = up.hail_upload(schema, raw, index_columns=(),
                             replication=replication, partition_size=P_PART,
                             n_nodes=4)
    gv.govern(lazy, max_indexed_blocks=budget)
    cfg = mr.AdaptiveConfig(offer_rate=offer_rate)
    expected_frac = min(blocks, budget) / blocks
    for phase, col in enumerate(cols):
        lo, hi = sorted(((seed >> 3) % VMAX, (seed >> 7) % VMAX))
        query = q.HailQuery(filter=(col, lo, hi), projection=(names[-1],))
        want = _rowset(eager, query)
        fracs = []
        for _ in range(math.ceil(1 / offer_rate) + 1):
            stats = mr.run_job(lazy, query, adaptive=cfg)
            assert stats.results["n_rows"] == len(want[ROWID])
            _assert_rows_equal(_rowset(lazy, query), want, query.projection)
            assert lazy.total_indexed_blocks() <= budget
            fracs.append(lazy.indexed_fraction(col))
        assert fracs == sorted(fracs)            # reconvergence is monotone
        assert fracs[-1] == pytest.approx(expected_frac)
        if phase > 0 and budget < 2 * blocks:
            # the shift had to evict the previous phase's (LRU) index
            assert lazy.indexed_fraction(cols[phase - 1]) < expected_frac


@settings(max_examples=4, deadline=None)
@given(st.sampled_from([0.25, 0.5, 0.75]),       # failure point
       st.sampled_from([0.5, 1.0]),              # offer rate
       st.integers(0, 2**31 - 1))                # data seed
def test_chaos_failover_races_demotion(fail_at, offer_rate, seed):
    """Node loss racing a demotion inside ONE job: the re-queued splits must
    full-scan the just-demoted replica correctly, the job must still be
    offered a rebuild, and the store must reconverge afterwards."""
    schema = _make_schema(3)
    _, raw = _make_raw(schema, 3, seed)
    a_col, b_col = schema.names[0], schema.names[1]
    eager, _ = up.hail_upload(schema, raw, [a_col, b_col],
                              partition_size=P_PART, n_nodes=4)
    lazy, _ = up.hail_upload(schema, raw, index_columns=(), replication=2,
                             partition_size=P_PART, n_nodes=4)
    gv.govern(lazy, max_indexed_blocks=3)
    cfg = mr.AdaptiveConfig(offer_rate=offer_rate)
    qa = q.HailQuery(filter=(a_col, 0, VMAX // 2),
                     projection=(schema.names[2],))
    qb = q.HailQuery(filter=(b_col, VMAX // 4, VMAX),
                     projection=(schema.names[2],))
    while lazy.indexed_fraction(a_col) < 1.0:
        mr.run_job(lazy, qa, adaptive=cfg)
    want = _rowset(eager, qb)
    stats = mr.run_job(lazy, qb, adaptive=cfg, fail_node_at=fail_at)
    assert stats.rescheduled_tasks > 0           # the failure really raced
    assert stats.blocks_demoted == 3             # ...a whole-replica demote
    assert stats.results["n_rows"] == len(want[ROWID])
    _assert_rows_equal(_rowset(lazy, qb), want, qb.projection)
    assert lazy.total_indexed_blocks() <= 3
    # the re-queued splits were still offered builds (or nothing was left)
    assert stats.blocks_indexed > 0 or lazy.indexed_fraction(b_col) == 1.0
    for _ in range(math.ceil(1 / offer_rate) + 1):
        if lazy.indexed_fraction(b_col) == 1.0:
            break
        mr.run_job(lazy, qb, adaptive=cfg)
    assert lazy.indexed_fraction(b_col) == 1.0   # reconverged post-chaos
    _assert_rows_equal(_rowset(lazy, qb), want, qb.projection)


# ---------------------------------------------------------------------------
# Demotion invariants (the destructive transition, unit level)
# ---------------------------------------------------------------------------


def test_demote_restores_upload_order_invariants(lazy_store, hail_store):
    from repro.core import checksum as ck
    mr.run_job(lazy_store, QA, adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    rep = lazy_store.replicas[0]
    assert rep.sort_key == "visitDate" and rep.indexed.all()
    before_mask = q._bad_mask(lazy_store, 0)
    untouched = lazy_store.replicas[1]           # still in upload order

    dropped = lazy_store.demote_replica(0)
    assert dropped == BLOCKS
    assert rep.sort_key is None and not rep.indexed.any()
    assert not np.asarray(rep.mins).any()
    # rows returned to upload order: bit-identical to the untouched replica
    for c in rep.cols:
        np.testing.assert_array_equal(np.asarray(rep.cols[c]),
                                      np.asarray(untouched.cols[c]))
    # checksums recomputed for the restored byte order, and they verify
    for b in range(BLOCKS):
        assert bool(ck.verify_block({c: v[b] for c, v in rep.cols.items()},
                                    {c: v[b] for c, v in
                                     rep.checksums.items()}))
    # namenode Dir_rep rewound
    for b in range(BLOCKS):
        info = lazy_store.namenode.dir_rep[(b, int(rep.nodes[b]))]
        assert info.sort_key is None
        assert not lazy_store.namenode.get_hosts_with_index(b, "visitDate")
    # bad-mask cache invalidated: bad rows back at upload positions
    after_mask = q._bad_mask(lazy_store, 0)
    assert after_mask is not before_mask
    np.testing.assert_array_equal(np.asarray(after_mask),
                                  np.asarray(lazy_store.bad_original))
    # row-sets still exact vs the eager oracle (pure full scan now)
    _assert_rows_equal(_rowset(lazy_store, QA), _rowset(hail_store, QA),
                       QA.projection)
    # ...and the replica is re-claimable by a different workload
    assert lazy_store.adaptive_replica_for("sourceIP") == 0
    mr.run_job(lazy_store, QB, adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    assert lazy_store.replicas[0].sort_key == "sourceIP"
    assert lazy_store.indexed_fraction("sourceIP") == 1.0


def test_demote_mid_rekey_replica_splices_only_indexed_blocks(lazy_store):
    """Demoting a partially indexed (mid-re-key) replica must restore the
    indexed blocks and leave the rest untouched — afterwards the replica is
    bit-identical (columns AND checksums) to a never-claimed one."""
    mr._build_block_indexes(lazy_store, 0, [1, 3], "visitDate",
                            partition_size=PART)
    assert int(lazy_store.replicas[0].indexed.sum()) == 2
    assert lazy_store.demote_replica(0) == 2
    rep, untouched = lazy_store.replicas[0], lazy_store.replicas[1]
    for c in rep.cols:
        np.testing.assert_array_equal(np.asarray(rep.cols[c]),
                                      np.asarray(untouched.cols[c]))
    for c in rep.checksums:
        np.testing.assert_array_equal(np.asarray(rep.checksums[c]),
                                      np.asarray(untouched.checksums[c]))


def test_no_demotion_without_build_budget(lazy_store):
    """A job that cannot rebuild (zero build quantum) must not destroy the
    LRU index: demotion is only worth it when the shifted workload can
    actually re-key the freed replica."""
    gv.govern(lazy_store, max_indexed_blocks=10 * BLOCKS)
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    mr.run_job(lazy_store, QA, adaptive=cfg)
    mr.run_job(lazy_store, QB, adaptive=cfg)
    mr.run_job(lazy_store, QC, adaptive=cfg)     # every replica claimed
    q4 = q.HailQuery(filter=("adRevenue", 0, 50_000),
                     projection=("sourceIP",))
    stats = mr.run_job(lazy_store, q4, adaptive=mr.AdaptiveConfig(
        offer_rate=1.0, max_build_per_job=0))
    assert stats.blocks_demoted == 0 and stats.blocks_indexed == 0
    assert lazy_store.indexed_fraction("visitDate") == 1.0   # A survived
    stats = mr.run_job(lazy_store, q4, adaptive=mr.AdaptiveConfig(
        offer_rate=0.0))
    assert stats.blocks_demoted == 0
    assert lazy_store.indexed_fraction("visitDate") == 1.0


def test_budget_backstop_at_commit(lazy_store):
    """commit_block_indexes must trim direct commits to the budget's room —
    the budget holds no matter who commits."""
    gv.govern(lazy_store, max_indexed_blocks=2)
    built = mr._build_block_indexes(lazy_store, 0, list(range(BLOCKS)),
                                    "visitDate", partition_size=PART)
    assert built == 2
    assert lazy_store.total_indexed_blocks() == 2
    assert lazy_store.replicas[0].sort_key == "visitDate"
    # zero room: the commit is refused entirely and must NOT claim
    built = mr._build_block_indexes(lazy_store, 1, [0, 1], "sourceIP",
                                    partition_size=PART)
    assert built == 0
    assert lazy_store.replicas[1].sort_key is None
    assert lazy_store.total_indexed_blocks() == 2


def test_budget_in_bytes(lazy_store):
    per_block = lazy_store.replicas[0].nbytes // lazy_store.n_blocks
    gov = gv.govern(lazy_store, max_indexed_bytes=3 * per_block)
    assert gov.budget_blocks(lazy_store) == 3
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    mr.run_job(lazy_store, QA, adaptive=cfg)
    assert lazy_store.total_indexed_blocks() == 3
    assert lazy_store.indexed_fraction("visitDate") == 3 / BLOCKS


def test_victim_policy_is_lru(lazy_store):
    gv.govern(lazy_store, max_indexed_blocks=2 * BLOCKS)
    gov = lazy_store.governor
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    mr.run_job(lazy_store, QA, adaptive=cfg)     # replica 0 <- visitDate
    mr.run_job(lazy_store, QB, adaptive=cfg)     # replica 1 <- sourceIP
    rid_a = lazy_store.replica_for("visitDate")
    rid_b = lazy_store.replica_for("sourceIP")
    mr.run_job(lazy_store, QB)                   # B is warmer than A
    assert gov.victim(lazy_store, protect=("duration",)) == rid_a
    mr.run_job(lazy_store, QA)                   # now A is warmer than B
    mr.run_job(lazy_store, QA)
    assert gov.victim(lazy_store, protect=("duration",)) == rid_b
    # the replica being converged on is protected from its own eviction
    assert gov.victim(lazy_store, protect=("visitDate",)) == rid_b
    assert gov.victim(lazy_store,
                      protect=("visitDate", "sourceIP")) is None


def test_fresh_index_is_not_the_lru_victim(lazy_store):
    """A just-committed index that has never served a read must not score
    as the coldest victim: plan() routes full scans to the FIRST alive
    replica, so the replica being built during a shift job may finish with
    zero read records — the commit-time recency stamp keeps the next shift
    from thrashing the index the store just paid to build."""
    gv.govern(lazy_store, max_indexed_blocks=2 * BLOCKS)
    gov = lazy_store.governor
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    mr.run_job(lazy_store, QA, adaptive=cfg)     # old workload: visitDate
    mr.run_job(lazy_store, QB, adaptive=cfg)     # shift: builds sourceIP
    rid_a = lazy_store.replica_for("visitDate")
    rid_b = lazy_store.replica_for("sourceIP")
    # the B build's reads were all attributed to replica rid_a (alive[0]);
    # rid_b's only log entry is its commit stamp — still newer than A
    rec_b = lazy_store.access_log.get(rid_b, "sourceIP")
    rec_a = lazy_store.access_log.get(rid_a, "visitDate")
    assert rec_b is not None and rec_b.last_used > rec_a.last_used
    assert gov.victim(lazy_store, protect=("duration",)) == rid_a


def test_access_log_attribution(lazy_store):
    """Record readers attribute per-(replica, column) hits/misses into the
    persistent AccessLog AND reader_stats' per-column counters."""
    from repro.kernels import ops
    cfg = mr.AdaptiveConfig(offer_rate=1.0)
    with ops.stats_scope() as s:
        mr.run_job(lazy_store, QA, adaptive=cfg)      # all full scans
        mr.run_job(lazy_store, QA)                    # all index scans
    assert s.dispatches["full_scan_blocks[visitDate]"] == BLOCKS
    assert s.dispatches["index_scan_blocks[visitDate]"] == BLOCKS
    log = lazy_store.access_log
    assert log is not None and log.clock > 0
    rid = lazy_store.replica_for("visitDate")
    rec = log.get(rid, "visitDate")
    assert rec is not None and rec.hits >= BLOCKS
    totals = log.col_totals("visitDate")
    assert totals.hits >= BLOCKS and totals.misses >= BLOCKS
    # demotion forgets the replica's history (a re-claim starts cold)
    lazy_store.demote_replica(rid)
    assert log.get(rid, "visitDate") is None


# ---------------------------------------------------------------------------
# Regression: replica_for prefers the most-indexed replica sharing a key
# ---------------------------------------------------------------------------


def test_replica_for_prefers_highest_indexed_fraction(lazy_store):
    """After demote→re-claim two replicas can share a sort_key with very
    different indexed fractions; planning must read from the one that
    qualifies the most blocks."""
    mr._build_block_indexes(lazy_store, 0, [0], "visitDate",
                            partition_size=PART)
    mr._build_block_indexes(lazy_store, 1, list(range(BLOCKS)), "visitDate",
                            partition_size=PART)
    assert lazy_store.replicas[0].sort_key == "visitDate"
    assert lazy_store.replicas[1].sort_key == "visitDate"
    assert lazy_store.replica_for("visitDate") == 1
    assert lazy_store.replica_by_key("visitDate") == 1   # alias agrees
    assert lazy_store.indexed_fraction("visitDate") == 1.0
    # the adaptive path keeps converging the most-indexed replica
    assert lazy_store.adaptive_replica_for("visitDate") == 1
    qp = q.plan(lazy_store, QA)
    assert qp.index_scan.all()
    # ties break toward the lowest replica id
    mr._build_block_indexes(lazy_store, 0, list(range(1, BLOCKS)),
                            "visitDate", partition_size=PART)
    assert lazy_store.replica_for("visitDate") == 0
