"""Corruption resilience: fault injection, read-path checksum verification,
replica quarantine, bounded-retry recovery, index-preserving repair, the
background scrubber, and the chaos property test (seeded corruption of up
to R-1 replicas interleaved with adaptive commits, demotions and a node
failure never changes any query's row-set; all-R corruption surfaces
``UnrecoverableDataError`` — never silent wrong rows).

All stores here are built FRESH per test (never the session fixtures): the
whole point of the module is to corrupt them.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import checksum as ck
from repro.core import governor as gv
from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.fault import (CorruptBlockError, FaultInjector,
                              RecoveryConfig, UnrecoverableDataError)
from repro.core.parse import format_rows
from repro.core.schema import ROWID
from repro.kernels import ops
from repro.runtime.jobserver import HailServer, ServerConfig
from repro.runtime.scrubber import ScrubConfig, Scrubber

ROWS, BLOCKS, PART = 256, 4, 64
KEYS = ["visitDate", "sourceIP", "adRevenue"]
QUERY = q.HailQuery(filter=("visitDate", 8000, 9000),
                    projection=("sourceIP",))


@pytest.fixture(scope="module")
def raw():
    cols = sc.gen_uservisits(ROWS * BLOCKS, seed=11)
    blocks = format_rows(sc.USERVISITS, cols,
                         bad_fraction=0.002).reshape(BLOCKS, ROWS, -1)
    return cols, blocks


@pytest.fixture(scope="module")
def oracle(raw):
    """Query -> sorted matching rowids, from the PRISTINE column data."""
    cols, blocks = raw
    store, _ = up.hail_upload(sc.USERVISITS, blocks, KEYS,
                              partition_size=PART, n_nodes=6)
    bad = np.asarray(store.bad_original).reshape(-1)

    def expect(query):
        col, lo, hi = query.filter
        v = np.asarray(cols[col])
        return np.nonzero((v >= lo) & (v <= hi) & ~bad)[0]
    return expect


def _eager(raw):
    store, _ = up.hail_upload(sc.USERVISITS, raw[1], KEYS,
                              partition_size=PART, n_nodes=6)
    return store


def _lazy(raw):
    store, _ = up.hail_upload(sc.USERVISITS, raw[1], index_columns=(),
                              partition_size=PART, n_nodes=6)
    return store


def _rowids(out, mask):
    return np.sort(out[ROWID].reshape(-1)[mask.reshape(-1)])


# ---------------------------------------------------------------------------
# injector + detection primitives
# ---------------------------------------------------------------------------


def test_injector_deterministic(raw):
    s1, s2 = _eager(raw), _eager(raw)
    e1 = [FaultInjector(s1, seed=9).corrupt_chunk(1, 2) for _ in range(2)]
    e2 = [FaultInjector(s2, seed=9).corrupt_chunk(1, 2) for _ in range(2)]
    assert e1 == e2                       # same seed, same fault sequence
    np.testing.assert_array_equal(
        np.asarray(s1.replicas[1].cols[e1[0].col]),
        np.asarray(s2.replicas[1].cols[e1[0].col]))
    s3 = _eager(raw)
    FaultInjector(s3, seed=9).corrupt_chunk(1, 2)
    assert not s3.verify_block(1, 2)      # and the fault is detectable
    assert s3.verify_block(0, 2)          # other replicas untouched


@given(st.integers(0, ROWS - 1), st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_single_bitflip_always_detected(pos, bit):
    """A one-bit flip moves one byte by ±2^k (k<8), which cannot cancel
    mod 65521 — the Fletcher-style chunk checksum must ALWAYS change."""
    data = jnp.arange(ROWS, dtype=jnp.int32)
    sums = ck.chunk_checksums(data)
    flipped = data.at[pos].set(jnp.int32(int(data[pos]) ^ (1 << bit)))
    assert not bool(ck.verify(flipped, sums).all())


def test_verify_blocks_batched_counters(raw):
    store = _eager(raw)
    rep = store.replicas[0]
    names = sorted(rep.cols)
    data = jnp.stack([rep.cols[c] for c in names])
    sums = jnp.stack([rep.checksums[c] for c in names])
    with ops.stats_scope() as s:
        ok = np.asarray(ops.verify_blocks(data, sums))
    assert ok.all() and ok.shape == (len(names), BLOCKS)
    assert s.dispatches["verify_blocks"] == 1          # ONE fused dispatch
    assert s.dispatches["verify_block_cols"] == len(names) * BLOCKS


# ---------------------------------------------------------------------------
# read path: detect -> quarantine -> re-plan -> identical rows
# ---------------------------------------------------------------------------


def test_job_recovers_from_chunk_corruption(raw, oracle):
    store = _eager(raw)
    FaultInjector(store, seed=1).corrupt_chunk(0, 2, "visitDate")
    stats = mr.run_job(store, QUERY, reduce_fn=_rowids)
    np.testing.assert_array_equal(stats.results["reduce"], oracle(QUERY))
    assert stats.blocks_quarantined == 1
    assert stats.corrupt_retries == 1
    assert store.is_quarantined(0, 2)
    # the quarantined copy is out of planning until repaired
    assert 0 not in store.alive_replica_ids(2)


def test_job_recovers_from_root_corruption(raw, oracle):
    """The root directory is not checksummed — a scrambled directory would
    silently mis-prune partitions.  The consistency check (mins re-derived
    from the verified key column) must catch it."""
    store = _eager(raw)
    FaultInjector(store, seed=2).corrupt_root(0, 1)
    stats = mr.run_job(store, QUERY, reduce_fn=_rowids)
    np.testing.assert_array_equal(stats.results["reduce"], oracle(QUERY))
    assert stats.blocks_quarantined == 1


def test_truncated_checksums_treated_as_corrupt(raw, oracle):
    """Intact data whose checksums are lost is UNPROVABLE data: it must be
    quarantined and repaired (fresh checksums included), not trusted."""
    store = _eager(raw)
    FaultInjector(store, seed=3).truncate_checksums(0, 0, "sourceIP")
    stats = mr.run_job(store, QUERY, reduce_fn=_rowids)
    np.testing.assert_array_equal(stats.results["reduce"], oracle(QUERY))
    assert store.is_quarantined(0, 0)
    rs = store.repair_blocks()
    assert rs.blocks_repaired == 1
    assert store.verify_block(0, 0)


def test_all_replicas_corrupt_raises_not_wrong_rows(raw):
    store = _eager(raw)
    FaultInjector(store, seed=4).corrupt_replicas(
        1, store.replication, "visitDate")
    with pytest.raises(UnrecoverableDataError):
        mr.run_job(store, QUERY)


def test_retry_budget_bounded(raw):
    """Satellite: replicas dying faster than the retry budget must surface
    a typed error, not loop.  max_retries=0 means the FIRST corruption
    retry already exceeds the budget."""
    store = _eager(raw)
    FaultInjector(store, seed=5).corrupt_chunk(0, 2, "visitDate")
    with pytest.raises(UnrecoverableDataError):
        mr.run_job(store, QUERY, recovery=RecoveryConfig(max_retries=0))


def test_corruption_composes_with_node_failure(raw, oracle):
    store = _eager(raw)
    inj = FaultInjector(store, seed=6)
    inj.corrupt_chunk(1, 3)                  # rot on replica 1 ...
    stats = mr.run_job(store, QUERY, fail_node_at=0.5,  # ... plus a dead node
                       reduce_fn=_rowids)
    np.testing.assert_array_equal(stats.results["reduce"], oracle(QUERY))
    assert not store.namenode.dead           # revived at job end


# ---------------------------------------------------------------------------
# repair preserves the per-replica clustered index
# ---------------------------------------------------------------------------


def test_repair_matches_fresh_eager_upload(raw, oracle):
    """Acceptance: after repair, the victim replica's sort_key, indexed
    flags, root directory, columns and checksums equal a freshly uploaded
    eager store's, and the governor's AccessLog recency survives."""
    store = _eager(raw)
    # build up AccessLog recency with real traffic
    mr.run_job(store, QUERY)
    log_before = dict(store.access_log.counts)
    inj = FaultInjector(store, seed=7)
    inj.corrupt_column(0, 3, "adRevenue")    # whole-column rot
    inj.corrupt_root(1, 0)                   # directory rot, other replica
    for rid, b in ((0, 3), (1, 0)):
        store.quarantine_block(rid, b)
    rs = store.repair_blocks()
    assert rs.blocks_repaired == 2 and rs.unrepairable == 0
    assert not store.namenode.quarantined

    fresh = _eager(raw)
    for rid in range(store.replication):
        got, want = store.replicas[rid], fresh.replicas[rid]
        assert got.sort_key == want.sort_key
        np.testing.assert_array_equal(got.indexed, want.indexed)
        np.testing.assert_array_equal(np.asarray(got.mins),
                                      np.asarray(want.mins))
        for c in want.cols:
            np.testing.assert_array_equal(np.asarray(got.cols[c]),
                                          np.asarray(want.cols[c]))
            np.testing.assert_array_equal(np.asarray(got.checksums[c]),
                                          np.asarray(want.checksums[c]))
    assert dict(store.access_log.counts) == log_before  # recency preserved
    stats = mr.run_job(store, QUERY, reduce_fn=_rowids)
    np.testing.assert_array_equal(stats.results["reduce"], oracle(QUERY))


def test_repair_unindexed_block_restores_upload_order(raw):
    store = _lazy(raw)
    FaultInjector(store, seed=8).corrupt_chunk(2, 1)
    store.quarantine_block(2, 1)
    rs = store.repair_blocks()
    assert rs.blocks_repaired == 1
    fresh = _lazy(raw)
    for c in fresh.replicas[2].cols:
        np.testing.assert_array_equal(
            np.asarray(store.replicas[2].cols[c]),
            np.asarray(fresh.replicas[2].cols[c]))
    assert store.verify_block(2, 1)


def test_unrepairable_block_stays_quarantined(raw):
    store = _eager(raw)
    inj = FaultInjector(store, seed=9)
    inj.corrupt_replicas(2, store.replication, "visitDate")  # no donor left
    for rid in range(store.replication):
        store.quarantine_block(rid, 2)
    rs = store.repair_blocks()
    assert rs.blocks_repaired == 0
    assert rs.unrepairable == store.replication
    assert len(store.namenode.quarantined) == store.replication
    with pytest.raises(UnrecoverableDataError):
        q.plan(store, QUERY)


# ---------------------------------------------------------------------------
# demote x quarantine interop (satellite regression)
# ---------------------------------------------------------------------------


def test_demoted_quarantined_replica_stays_out_until_repaired(raw, oracle):
    """Regression: a replica demoted WHILE quarantined must not resurface
    in planning until repaired — and demotion must not launder the corrupt
    block by re-checksumming it."""
    store = _eager(raw)
    FaultInjector(store, seed=10).corrupt_chunk(0, 1, "visitDate")
    store.quarantine_block(0, 1)
    dropped = store.demote_replica(0)
    assert dropped == BLOCKS                 # budget freed for all blocks
    assert store.is_quarantined(0, 1)        # quarantine survives demotion
    assert store.replica_for("visitDate") is None
    plan = q.plan(store, QUERY)
    assert plan.replica_for_block[1] != 0    # still excluded from planning
    # demotion did NOT recompute checksums over the corrupt bytes
    assert not store.verify_block(0, 1)
    rs = store.repair_blocks()               # repairs to upload order now
    assert rs.blocks_repaired == 1
    assert store.verify_block(0, 1)
    assert 0 in store.alive_replica_ids(1)   # back in service
    # the replica re-claims through the ordinary adaptive path
    mr.run_job(store, QUERY, adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    stats = mr.run_job(store, QUERY, reduce_fn=_rowids)
    np.testing.assert_array_equal(stats.results["reduce"], oracle(QUERY))


def test_commit_skips_quarantined_blocks(raw):
    store = _lazy(raw)
    FaultInjector(store, seed=11).corrupt_chunk(0, 2, "visitDate")
    for _ in range(2):                       # enough budget for every block
        mr.run_job(store, QUERY, adaptive=mr.AdaptiveConfig(offer_rate=1.0))
    rep = store.replicas[0]
    assert store.is_quarantined(0, 2)        # build-path verify caught it
    assert not rep.indexed[2]                # and refused to index it
    assert rep.indexed.sum() == BLOCKS - 1   # the clean blocks committed


# ---------------------------------------------------------------------------
# server + cache + scrubber
# ---------------------------------------------------------------------------


def test_server_flush_recovers_cold_cache(raw, oracle):
    store = _eager(raw)
    srv = HailServer(store, ServerConfig(max_batch=2))
    queries = [q.HailQuery(filter=("visitDate", 7500 + 300 * i,
                                   8700 + 300 * i),
                           projection=("sourceIP",)) for i in range(2)]
    FaultInjector(store, seed=12).corrupt_chunk(0, 0, "visitDate")
    tickets = [srv.submit(qq) for qq in queries]
    fs = srv.flush()
    assert fs.blocks_quarantined == 1 and fs.corrupt_retries >= 1
    for t, qq in zip(tickets, queries):
        np.testing.assert_array_equal(np.sort(t.result.rows[ROWID]),
                                      oracle(qq))


def test_verification_amortized_to_cache_fills(raw):
    """Acceptance: verification runs on BlockCache FILLS only — a warm
    flush repeats zero verify dispatches (cached gathers were proven at
    fill time), which is why the clean-path tax is bounded.
    result_cache off: the warm flush must reach the block-cache tier (the
    result tier would answer it before any gather happens)."""
    store = _eager(raw)
    srv = HailServer(store, ServerConfig(max_batch=2, result_cache=False))
    queries = [q.HailQuery(filter=("visitDate", 7600 + 100 * i,
                                   8800 + 100 * i),
                           projection=("sourceIP",)) for i in range(2)]
    for qq in queries:
        srv.submit(qq)
    with ops.stats_scope() as cold:
        srv.flush()
    assert cold.dispatches["verify_blocks"] > 0
    for qq in queries:
        srv.submit(qq)
    with ops.stats_scope() as warm:
        srv.flush()
    assert warm.dispatches["verify_blocks"] == 0
    assert warm.dispatches["cache_hits"] > 0


def test_result_cache_invalidated_by_quarantine_and_repair(raw, oracle):
    """The result tier is dropped by BOTH corruption-side transitions:
    quarantine (the cached answer's plan just lost a replica) and repair
    (the store's bytes changed back).  Either way the next repeat query
    re-scans and stays exact — and once the store is stable again, the
    repeat is a zero-dispatch hit once more.  Block cache OFF so every
    scan verifies (a warm tier-1 gather would hide the corruption from
    this flush — detection is amortized to fills by design)."""
    store = _eager(raw)
    srv = HailServer(store, ServerConfig(max_batch=2, cache=False))
    t0 = srv.submit(QUERY)
    srv.flush()                               # fill at version v0
    assert not t0.result.from_cache
    t1 = srv.submit(QUERY)
    with ops.stats_scope() as s:
        srv.flush()
    assert t1.result.from_cache and s.dispatches["hail_read"] == 0

    # inject corruption: the cached answer PREDATES it and nothing has
    # scanned the corrupt copy yet, so serving the repeat from cache is
    # still exact (a scan would detect, re-plan, and compute these rows)
    v0 = store.version
    FaultInjector(store, seed=21).corrupt_chunk(0, 1, "visitDate")
    t2 = srv.submit(QUERY)
    srv.flush()
    assert t2.result.from_cache and store.version == v0
    np.testing.assert_array_equal(np.sort(t2.result.rows[ROWID]),
                                  oracle(QUERY))

    # a NEW range scans, detects, quarantines: version bumps, tier drops —
    # now the old repeat must RE-SCAN (against the re-planned replica set)
    probe = q.HailQuery(filter=("visitDate", 7900, 9100),
                        projection=("sourceIP",))
    tp = srv.submit(probe)
    fs = srv.flush()
    assert fs.blocks_quarantined == 1 and store.version > v0
    np.testing.assert_array_equal(np.sort(tp.result.rows[ROWID]),
                                  oracle(probe))
    t3 = srv.submit(QUERY)
    srv.flush()
    assert not t3.result.from_cache
    np.testing.assert_array_equal(np.sort(t3.result.rows[ROWID]),
                                  oracle(QUERY))

    # repair restores the block and bumps the version again: everything
    # filled against the quarantined plan is unreachable and dropped
    v_q = store.version
    rs = store.repair_blocks()
    assert rs.blocks_repaired == 1 and store.version > v_q
    assert len(store.result_cache) == 0
    t4 = srv.submit(QUERY)
    srv.flush()
    assert not t4.result.from_cache           # re-scan on the healed store
    np.testing.assert_array_equal(np.sort(t4.result.rows[ROWID]),
                                  oracle(QUERY))
    t5 = srv.submit(QUERY)
    with ops.stats_scope() as s:
        srv.flush()
    assert t5.result.from_cache and s.dispatches["hail_read"] == 0
    np.testing.assert_array_equal(np.sort(t5.result.rows[ROWID]),
                                  oracle(QUERY))


def test_scrubber_finds_cold_corruption_before_queries(raw):
    store = _eager(raw)
    scrub = Scrubber(store, ScrubConfig(blocks_per_tick=4)).attach()
    FaultInjector(store, seed=13).corrupt_chunk(2, 3, "adRevenue")
    # no query ever touches the corrupt copy; the scrubber must still find
    # it within one full revolution and repair it
    for _ in range(3 * BLOCKS // 4 + 1):
        scrub.tick()
    assert scrub.stats.blocks_quarantined == 1
    assert scrub.stats.blocks_repaired == 1
    assert not store.namenode.quarantined
    assert all(store.verify_block(r, b)
               for r in range(store.replication) for b in range(BLOCKS))


def test_job_boundary_scrub_ticks(raw):
    store = _eager(raw)
    scrub = Scrubber(store, ScrubConfig(blocks_per_tick=2)).attach()
    stats = mr.run_job(store, QUERY)
    assert stats.scrub_s > 0.0
    assert scrub.stats.ticks == 1
    stats = mr.run_job(store, QUERY,
                       recovery=RecoveryConfig(scrub=False))
    assert stats.scrub_s == 0.0
    assert scrub.stats.ticks == 1            # scrub=False skips the tick


def test_cache_invalidate_blocks_is_block_granular():
    from repro.core.cache import BlockCache
    cache = BlockCache()
    cache.put((0, (0, 1), "visitDate", ("sourceIP",)), (np.zeros(4),))
    cache.put((0, (2, 3), "visitDate", ("sourceIP",)), (np.zeros(4),))
    cache.put((1, (0, 1), "visitDate", ("sourceIP",)), (np.zeros(4),))
    cache.invalidate_blocks(0, [1])
    assert cache.get((0, (0, 1), "visitDate", ("sourceIP",))) is None
    assert cache.get((0, (2, 3), "visitDate", ("sourceIP",))) is not None
    assert cache.get((1, (0, 1), "visitDate", ("sourceIP",))) is not None


# ---------------------------------------------------------------------------
# the chaos property test (acceptance criterion)
# ---------------------------------------------------------------------------


@given(st.integers(0, 10 ** 6))
@settings(max_examples=4, deadline=None)
def test_chaos_rowsets_always_match_oracle(raw, oracle, seed):
    """Seeded corruption of up to R-1 replicas per block, interleaved with
    adaptive commits, governor demotions and a node failure: every job's
    row-set equals the pristine oracle's."""
    rng = np.random.default_rng(seed)
    store = _lazy(raw)
    gv.govern(store, max_indexed_blocks=BLOCKS, claim_miss_jobs=1)
    Scrubber(store, ScrubConfig(blocks_per_tick=2)).attach()
    inj = FaultInjector(store, seed=seed)
    queries = [q.HailQuery(filter=("visitDate", 7800, 8800),
                           projection=("sourceIP",)),
               q.HailQuery(filter=("sourceIP", 0, 2 ** 30),
                           projection=("adRevenue",))]
    victims = rng.permutation(BLOCKS)[:3]
    fail_job = int(rng.integers(0, 5))
    for j in range(5):
        if j < len(victims):               # fresh victim block each round,
            inj.corrupt_replicas(           # at most R-1 replicas corrupt
                int(victims[j]), int(rng.integers(1, store.replication)))
        query = queries[j % 2]              # alternating workload: commits,
        stats = mr.run_job(                 # demotions, re-claims
            store, query, reduce_fn=_rowids,
            adaptive=mr.AdaptiveConfig(offer_rate=0.5),
            fail_node_at=0.5 if j == fail_job else None)
        np.testing.assert_array_equal(stats.results["reduce"],
                                      oracle(query))
