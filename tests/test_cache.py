"""Unit tests for the tiered serving-layer caches (core/cache.py).

Covers the SLRU mechanics and scan-resistant admission of ``BlockCache``
(the fix for the half-budget pure-LRU thrash bench_server documented:
0.0 hit rate, 186 evictions), the byte-accounting invariant under every
mutation kind (``recount()`` oracle), block-granular invalidation with
true-residual re-accounting, and the ``ResultCache`` tier's exact /
subsumed / version-keyed lookup semantics.  Integration behavior (server
flushes, governor attribution replay, corruption races) lives in
test_server.py / test_fault.py.
"""
import numpy as np
import pytest

from repro.core.cache import BlockCache, ResultCache, _nbytes


def _val(n_blocks: int, width: int = 4):
    """A pytree-ish cached value: leading axis = gathered blocks."""
    return {"key": np.arange(n_blocks * width, dtype=np.int64)
            .reshape(n_blocks, width),
            "mask": np.ones((n_blocks, width), dtype=np.int64)}


UNIT = _nbytes(_val(1))          # bytes of a one-block value


class _Log:
    def __init__(self, heats):
        self._h = dict(heats)

    def heat(self, rid, col):
        return self._h.get((rid, col), 0)


class _Store:
    """Just enough store for the admission filter's heat tie-break."""
    def __init__(self, heats=()):
        self.access_log = _Log(heats)
        self.block_cache = None


def _key(rid=0, blocks=(0,), col="c", proj=("p",)):
    return (rid, tuple(blocks), col, proj)


# ---------------------------------------------------------------------------
# SLRU mechanics
# ---------------------------------------------------------------------------


def test_slru_promotion_and_protected_overflow_demotion():
    # capacity 2 entries, protected capped at 1 entry (frac 0.5)
    c = BlockCache(capacity_bytes=2 * UNIT, protected_frac=0.5)
    a, b = _key(blocks=(0,)), _key(blocks=(1,))
    c.put(a, _val(1))
    c.put(b, _val(1))
    assert a in c._probation and b in c._probation

    assert c.get(a) is not None          # proven reuse: promote
    assert a in c._protected and c.stats.promotions == 1

    # promoting b overflows the protected segment -> its LRU (a) demotes
    # back to probation MRU: still RESIDENT, but evictable again
    assert c.get(b) is not None
    assert b in c._protected and a in c._probation
    assert c.stats.promotions == 2 and len(c) == 2
    assert c.recount() == c.stats.bytes_cached == 2 * UNIT


def test_refresh_with_larger_value_still_respects_capacity():
    c = BlockCache(capacity_bytes=3 * UNIT, scan_resistant=False)
    a, b = _key(blocks=(0,)), _key(blocks=(1,))
    c.put(a, _val(1))
    c.put(b, _val(1))
    c.put(a, _val(2))                    # refresh GROWS a to 2 units
    assert c.stats.bytes_cached <= c.capacity_bytes
    assert c.recount() == c.stats.bytes_cached
    assert a in c                        # the refreshed entry survives
    c.put(a, _val(3))                    # grows to the full budget
    assert c.stats.bytes_cached == 3 * UNIT and b not in c


# ---------------------------------------------------------------------------
# Scan-resistant admission
# ---------------------------------------------------------------------------


def test_one_touch_scan_cannot_evict_proven_reuse():
    c = BlockCache(capacity_bytes=2 * UNIT)
    hot = [_key(blocks=(i,)) for i in (0, 1)]
    for k in hot:
        c.get(k)                         # miss: ghost freq 1
        c.put(k, _val(1))
    for k in hot:
        assert c.get(k) is not None      # ghost freq 2, promoted

    # a sequential one-touch scan streams 10 cold candidates: every one
    # must be REJECTED (freq 1 < resident freq 2), residents stay hot
    for i in range(10, 20):
        k = _key(blocks=(i,))
        assert c.get(k) is None
        c.put(k, _val(1))
    assert c.stats.admission_rejects == 10
    assert c.stats.evictions == 0
    for k in hot:
        assert c.get(k) is not None


def test_frequent_candidate_displaces_one_touch_resident():
    c = BlockCache(capacity_bytes=2 * UNIT)
    a, b, cand = (_key(blocks=(i,)) for i in (0, 1, 2))
    c.put(a, _val(1))                    # never demanded: freq 0
    c.put(b, _val(1))
    for _ in range(3):
        c.get(cand)                      # three demands: freq 3
    c.put(cand, _val(1))
    assert cand in c and a not in c      # probation LRU evicted
    assert c.stats.evictions == 1 and c.stats.admission_rejects == 0
    assert c.recount() == c.stats.bytes_cached == 2 * UNIT


def test_admission_tie_broken_by_governor_column_heat():
    heats = {(0, "hot"): 5}
    resident = _key(rid=0, blocks=(0,), col="cold")
    cand_hot = _key(rid=0, blocks=(1,), col="hot")
    cand_cold = _key(rid=0, blocks=(2,), col="cold")

    # equal ghost frequency, hotter column -> admitted, resident evicted
    c = BlockCache(capacity_bytes=UNIT).attach(_Store(heats))
    c.get(resident)
    c.put(resident, _val(1))
    c.get(cand_hot)
    c.put(cand_hot, _val(1))
    assert cand_hot in c and resident not in c

    # equal ghost frequency, equal heat -> rejected, resident stays
    c = BlockCache(capacity_bytes=UNIT).attach(_Store(heats))
    c.get(resident)
    c.put(resident, _val(1))
    c.get(cand_cold)
    c.put(cand_cold, _val(1))
    assert resident in c and cand_cold not in c
    assert c.stats.admission_rejects == 1


def test_half_budget_sequential_loop_pure_lru_vs_scan_resistant():
    """The bench failure mode in miniature: 4 one-unit working-set keys
    looped sequentially through a 2-unit budget."""
    keys = [_key(blocks=(i,)) for i in range(4)]

    def loop(cache, rounds=3):
        for _ in range(rounds):
            for k in keys:
                if cache.get(k) is None:
                    cache.put(k, _val(1))
        return cache.stats

    lru = loop(BlockCache(capacity_bytes=2 * UNIT, scan_resistant=False))
    assert lru.hit_rate == 0.0 and lru.evictions > 0   # the old thrash

    slru = loop(BlockCache(capacity_bytes=2 * UNIT))
    assert slru.hit_rate > 0.0                         # residents stay hot
    assert slru.admission_rejects > 0 and slru.evictions == 0


# ---------------------------------------------------------------------------
# Byte accounting (satellite: drift after block-granular invalidation)
# ---------------------------------------------------------------------------


def test_invalidate_blocks_reaccounts_true_residual_bytes():
    c = BlockCache()
    k = _key(rid=0, blocks=(0, 1, 2))
    c.put(k, _val(3))
    assert c.stats.bytes_cached == 3 * UNIT

    c.invalidate_blocks(0, [1])
    assert k not in c
    rk = _key(rid=0, blocks=(0, 2))
    assert rk in c
    # the residual is charged at its TRUE sliced size, not the
    # at-admission size — this was the accounting-drift bug
    assert c.stats.bytes_cached == 2 * UNIT
    assert c.recount() == c.stats.bytes_cached
    assert c.stats.invalidations == 1 and c.stats.partial_invalidations == 1

    # and the surviving rows are blocks 0 and 2 of the original gather
    residual = c.get(rk)
    np.testing.assert_array_equal(residual["key"],
                                  _val(3)["key"][np.asarray([0, 2])])


def test_invalidate_blocks_residual_key_collision_drops_duplicate():
    c = BlockCache()
    c.put(_key(blocks=(0, 2)), _val(2))      # residual key already cached
    c.put(_key(blocks=(0, 1, 2)), _val(3))
    c.invalidate_blocks(0, [1])
    assert len(c) == 1 and _key(blocks=(0, 2)) in c
    assert c.stats.bytes_cached == 2 * UNIT == c.recount()


def test_byte_accounting_invariant_under_random_mutation():
    """Property loop: after EVERY mutation kind, the stored per-entry
    sizes must recount to ``stats.bytes_cached`` and the capacity bound
    must hold."""
    rng = np.random.default_rng(7)
    cap = 10 * UNIT
    c = BlockCache(capacity_bytes=cap).attach(_Store())
    for step in range(300):
        op = rng.integers(0, 10)
        rid = int(rng.integers(0, 3))
        if op <= 4:                                     # get-then-maybe-put
            blocks = tuple(sorted(rng.choice(
                6, size=int(rng.integers(1, 4)), replace=False).tolist()))
            k = _key(rid=rid, blocks=blocks, col=f"c{rng.integers(0, 2)}")
            if c.get(k) is None:
                c.put(k, _val(len(blocks), width=int(rng.integers(2, 6))))
        elif op <= 6:
            c.invalidate_blocks(rid, rng.choice(
                6, size=int(rng.integers(1, 3)), replace=False).tolist())
        elif op <= 8:
            c.invalidate_replica(rid)
        else:
            c.clear()
        assert c.recount() == c.stats.bytes_cached, f"drift at step {step}"
        assert c.stats.bytes_cached <= cap
    assert c.stats.hits > 0 and c.stats.invalidations > 0
    assert c.stats.partial_invalidations > 0


# ---------------------------------------------------------------------------
# Tier 2: ResultCache
# ---------------------------------------------------------------------------


def _rows(vals, rowids):
    return {"c": np.asarray(vals), "__rowid__": np.asarray(rowids)}


def test_result_cache_exact_subsumed_and_version_semantics():
    rc = ResultCache()
    rc.put("c", 0, 10, ("c",), 0, _rows([1, 5, 9], [10, 11, 12]),
           ((0, 2, 1),))

    exact = rc.lookup("c", 0, 10, ("c",), 0)
    assert exact is not None and exact.n_rows == 3
    assert exact.attribution == ((0, 2, 1),)

    # a contained range narrows the cached superset by re-filtering
    sub = rc.lookup("c", 2, 6, ("c",), 0)
    assert sub is not None and sub.n_rows == 1
    np.testing.assert_array_equal(sub.rows["c"], [5])
    np.testing.assert_array_equal(sub.rows["__rowid__"], [11])
    assert sub.attribution == ((0, 2, 1),)
    assert rc.stats.subsumed_hits == 1 and rc.stats.hits == 2

    # a bumped store version makes every older entry unreachable
    assert rc.lookup("c", 0, 10, ("c",), 1) is None
    assert rc.stats.misses == 1


def test_result_cache_no_subsumption_without_filter_column_projected():
    rc = ResultCache()
    rc.put("c", 0, 10, ("x",), 0, {"x": np.arange(3),
                                   "__rowid__": np.arange(3)}, ())
    # exact repeat works regardless of projection...
    assert rc.lookup("c", 0, 10, ("x",), 0) is not None
    # ...but narrowing needs the filter column's values, which ("x",)
    # projections don't carry
    assert rc.lookup("c", 2, 6, ("x",), 0) is None


def test_result_cache_lru_capacity_and_invalidate():
    one = _nbytes(_rows([1], [1]))
    rc = ResultCache(capacity_bytes=2 * one)
    for i in range(3):
        rc.put("c", i, i, ("c",), 0, _rows([i], [i]), ())
    assert len(rc) == 2 and rc.stats.evictions == 1
    assert rc.stats.bytes_cached == 2 * one
    assert rc.lookup("c", 0, 0, ("c",), 0) is None      # LRU'd out
    assert rc.lookup("c", 2, 2, ("c",), 0) is not None

    rc.invalidate_store()
    assert len(rc) == 0 and rc.stats.bytes_cached == 0
    assert rc.stats.invalidations == 2


def test_result_cache_oversized_entry_not_admitted():
    rows = _rows(list(range(100)), list(range(100)))
    rc = ResultCache(capacity_bytes=_nbytes(rows) - 1)
    rc.put("c", 0, 99, ("c",), 0, rows, ())
    assert len(rc) == 0 and rc.stats.bytes_cached == 0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])


def test_result_cache_entries_are_mutation_proof():
    """Fill freezes the stored arrays (which ALIAS the caller's), so a
    caller scribbling on a served answer — or on the rows it just cached —
    raises instead of silently corrupting every future hit for that key."""
    rc = ResultCache()
    mine = _rows([1, 5, 9], [10, 11, 12])
    rc.put("c", 0, 10, ("c",), 0, mine, ())
    with pytest.raises(ValueError):
        mine["c"][0] = -1                     # the fill's own input froze
    hit = rc.lookup("c", 0, 10, ("c",), 0)
    with pytest.raises(ValueError):
        hit.rows["c"][:] = 0
    sub = rc.lookup("c", 2, 6, ("c",), 0)     # narrowed copies freeze too
    with pytest.raises(ValueError):
        sub.rows["__rowid__"][0] = 0
    np.testing.assert_array_equal(
        rc.lookup("c", 0, 10, ("c",), 0).rows["c"], [1, 5, 9])
