"""Per-arch smoke tests on REDUCED configs (assignment requirement): one
forward/train step on CPU asserting output shapes + no NaNs, plus
prefill->decode consistency (teacher-forced decode matches full forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced
from repro.models.model import forward, model_specs
from repro.dist.sharding import init_params, param_count
from repro.train.optimizer import OptCfg
from repro.train.step import (init_train_state, make_decode_step,
                              make_prefill_step, make_train_step)

KEY = jax.random.PRNGKey(0)
B, T = 2, 16


def _batch(cfg):
    b = {}
    if cfg.embed_inputs:
        b["tokens"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    else:
        b["inputs"] = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.bfloat16)
    if cfg.encoder is not None:
        b["tokens"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
        b["enc_inputs"] = jax.random.normal(KEY, (B, T, cfg.d_model),
                                            jnp.bfloat16)
    b["labels"] = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_step(arch):
    cfg = get_reduced(arch)
    state = init_train_state(cfg, OptCfg(), KEY)
    step = jax.jit(make_train_step(cfg, OptCfg()))
    new_state, metrics = step(state, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 1.0 < loss < 20.0
    assert int(new_state["step"]) == 1
    # params actually changed (vlm stub: embed table gets no gradient, so
    # check across all leaves, not just the first)
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert changed


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_prefill_decode_consistency(arch):
    """decode(pos=T | prefill(x[:T])) must match forward(x[:T+1])[-1]."""
    cfg = get_reduced(arch)
    params = init_params(model_specs(cfg), KEY)
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    tokens = jax.random.randint(KEY, (B, T + 1), 0, cfg.vocab)
    kw = {}
    b_pref = {}
    if cfg.embed_inputs:
        b_pref["tokens"] = tokens[:, :T]
        full_in = tokens
    else:
        emb = params["embed"].astype(jnp.bfloat16)[tokens]
        b_pref["inputs"] = emb[:, :T]
        full_in = emb
    if cfg.encoder is not None:
        enc = jax.random.normal(KEY, (B, T, cfg.d_model), jnp.bfloat16)
        b_pref["enc_inputs"] = enc
        kw["enc_inputs"] = enc
    # reference: full forward over T+1 tokens
    ref_logits = forward(params, cfg, full_in, mode="train", **kw)
    # prefill T (with decode headroom in the cache) then decode token T
    pf = jax.jit(make_prefill_step(cfg, max_len=T + 8))
    dc = jax.jit(make_decode_step(cfg))
    _, cache = pf(params, b_pref)
    got, _ = dc(params, cache, {"tokens": tokens[:, T],
                                "pos": jnp.asarray(T, jnp.int32)})
    a = np.asarray(ref_logits[:, T], np.float32)
    g = np.asarray(got, np.float32)
    # bf16 two-path tolerance
    np.testing.assert_allclose(g, a, atol=0.15, rtol=0.05)
    # and the argmax ranking agrees for nearly all rows
    agree = (a.argmax(-1) == g.argmax(-1)).mean()
    assert agree >= 0.9, f"argmax agreement {agree}"


def test_param_counts_match_published_scale():
    """Full configs land in the right parameter-count ballpark."""
    expect = {
        "llama3.2-1b": (1.0e9, 1.9e9),
        "gemma3-4b": (3.0e9, 5.0e9),
        "gemma3-12b": (10e9, 14e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "falcon-mamba-7b": (6.5e9, 8.5e9),
        "mixtral-8x22b": (120e9, 150e9),
        "arctic-480b": (430e9, 530e9),
        # our zamba2 reading (single shared block, no LoRA adapters) lands
        # at 1.98B — see DESIGN.md config notes
        "zamba2-2.7b": (1.8e9, 3.4e9),
        "whisper-medium": (0.6e9, 0.9e9),   # whisper-medium is 769M
        "qwen2-vl-72b": (65e9, 80e9),
    }
    from repro.configs import get_config
    for arch, (lo, hi) in expect.items():
        n = param_count(model_specs(get_config(arch)))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in [{lo/1e9},{hi/1e9}]B"
