"""Trace one served flush end to end and export it for Perfetto.

Runs a small HailServer workload (cold flush, warm repeat, frontend-driven
flush on the simulated clock) with the flight recorder on, validates the
exported JSON against the Chrome trace-event contract, and prints one
query's ``Ticket.explain()`` — the quickest way to see every layer of the
observability stack at once.

Usage:
    PYTHONPATH=src python examples/trace_server_flush.py [out.json]

Open the JSON at https://ui.perfetto.dev (or chrome://tracing): pid 1 is
the measured wall (flush/batch/split/cache tracks), pid 2 the simulated
cluster (per-node scheduler slices, per-tenant query slices, flow arrows
from arrival through every split a query waited on).

CI runs this with a small store and fails on any validation error — the
exported trace is uploaded as a build artifact.
"""
import sys

from repro.core import mapreduce as mr
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows
from repro.core.query import HailQuery
from repro.obs import metrics, trace
from repro.runtime import jobserver as js


def main(path: str = "trace_server_flush.json",
         blocks: int = 4, rows: int = 1024) -> int:
    cluster = mr.ClusterModel(n_nodes=6, map_slots=2)
    cols = sc.gen_uservisits(rows * blocks, seed=7)
    raw = format_rows(sc.USERVISITS, cols, bad_fraction=0.002)
    raw = raw.reshape(blocks, rows, -1)

    tracer = trace.install()
    reg0 = metrics.snapshot()
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"],
                              n_nodes=cluster.n_nodes)
    queries = [HailQuery(filter=("visitDate", lo, hi),
                         projection=("sourceIP",))
               for lo, hi in [(7305, 7670), (0, 20000), (42, 4242),
                              (123, 9999)]]

    # frontend-driven flushes: arrivals on the simulated clock, so the
    # trace carries per-tenant query slices + flow arrows into the splits
    server = js.HailServer(store, js.ServerConfig(max_batch=2,
                                                  cluster=cluster))
    fe = js.ServerFrontend(server, js.FlushPolicy(window_s=0.5))
    for k, qq in enumerate(queries):
        fe.offer(qq, tenant=f"tenant{k % 2}", at=k * 0.25)
    fe.drain()
    for k, qq in enumerate(queries):            # warm repeat: result tier
        fe.offer(qq, tenant=f"tenant{k % 2}", at=10.0 + k * 0.25)
    fe.drain()

    trace.uninstall()
    exported = tracer.export(path)
    errors = trace.validate_chrome_trace(exported)
    reg = metrics.delta(reg0)

    done = [t for t in server.tickets if t.status == "done"]
    print(done[0].explain().render())
    print(f"\ntrace: {len(exported['traceEvents'])} events -> {path}")
    print(f"validation errors: {errors if errors else 'none'}")
    print(f"registry: {len(reg)} series changed; "
          f"flush.queries={reg.get('flush.queries', 0):.0f}, "
          f"result-tier hits="
          f"{reg.get('flush.cache_hits{tier=result}', 0):.0f}")
    if errors:
        return 1
    if not all(t.explain().accounted_fraction >= 0.95 for t in done):
        print("explain() accounted under 95% of modeled latency")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:]))
