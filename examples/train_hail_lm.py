"""End-to-end driver: train an LM on HAIL-selected data.

The corpus lives in the HAIL block store; training-data selection
("domain in [0,3], i.e. the curated slice") is an INDEX SCAN, then the
standard train loop runs with checkpointing every --ckpt-every steps and
resume-from-latest on restart (kill it mid-run and start again to see).

Defaults are CPU-sized; --dim 512 --layers 12 --steps 300 gives the ~100M
configuration on real hardware.

  PYTHONPATH=src python examples/train_hail_lm.py --steps 60
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.ckpt import checkpoint as ck
from repro.configs.base import ModelCfg, StackCfg, dense_layer
from repro.data.pipeline import CorpusConfig, HailDataSource, build_corpus
from repro.train.optimizer import OptCfg
from repro.train.step import StepCfg, init_train_state, make_train_step


def model_cfg(dim: int, layers: int, vocab: int) -> ModelCfg:
    layer = dense_layer(dim, max(dim // 64, 2), max(dim // 128, 1),
                        4 * dim, head_dim=64)
    return ModelCfg(name=f"hail-lm-{dim}", family="dense", d_model=dim,
                    vocab=vocab, stack=StackCfg(pattern=(layer,),
                                                n_groups=layers))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--docs", type=int, default=2048)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/hail_lm_ckpt")
    args = ap.parse_args()

    # 1. corpus -> HAIL store (domain/quality/timestamp indexes)
    ccfg = CorpusConfig(n_docs=args.docs, seq_width=args.seq,
                        rows_per_block=256, partition_size=64, vocab=8192)
    t0 = time.time()
    store, stats = build_corpus(ccfg)
    print(f"corpus uploaded to HAIL in {time.time() - t0:.1f}s "
          f"({stats.n_indexes} indexes)")

    # 2. training-data selection = indexed HAIL query
    src = HailDataSource(store, ccfg, select=("domain", 0, 3),
                         batch_size=args.batch)
    print(f"selected {src.n_selected}/{args.docs} docs "
          f"(index scan: {src.used_index})")

    # 3. model + train loop with checkpoint/restore
    cfg = model_cfg(args.dim, args.layers, ccfg.vocab)
    opt = OptCfg(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    restored, step0 = ck.restore_latest(args.ckpt_dir, state)
    if restored is not None:
        state = restored
        print(f"resumed from checkpoint step {step0}")
    step_fn = jax.jit(make_train_step(cfg, opt, StepCfg(remat="none")))
    saver = ck.AsyncSaver()

    it = iter(src)
    t0 = time.time()
    start = int(state["step"])
    for i in range(start, args.steps):
        state, metrics = step_fn(state, next(it))
        if (i + 1) % 10 == 0:
            rate = args.batch * (args.seq - 1) * (i + 1 - start) / (time.time() - t0)
            print(f"step {i + 1:4d} loss={float(metrics['loss']):.3f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} tok/s={rate:.0f}")
        if (i + 1) % args.ckpt_every == 0:
            saver.save(state, args.ckpt_dir, i + 1)
    saver.wait()
    print(f"done: {args.steps} steps, final loss "
          f"{float(metrics['loss']):.3f}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
