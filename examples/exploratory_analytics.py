"""Bob's exploratory session (paper §1 + §6.4): a sequence of ad-hoc filters
over the same log, each hitting a DIFFERENT per-replica clustered index —
the workload HAIL was built for.  Includes the failover moment: a datanode
dies mid-session and queries keep working (some blocks fall back to scans).

  PYTHONPATH=src python examples/exploratory_analytics.py
"""
import numpy as np

from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows


def show(name, sql, job):
    print(f"{name}: {sql}")
    print(f"   -> {job.results['n_rows']} rows | {job.n_tasks} tasks | "
          f"{job.bytes_read / 1e6:.2f} MB read | "
          f"{job.end_to_end_s:.2f}s simulated end-to-end")


def main():
    cols = sc.gen_uservisits(32 * 4096, seed=1)
    raw = format_rows(sc.USERVISITS, cols).reshape(32, 4096, -1)
    store, _ = up.hail_upload(sc.USERVISITS, raw,
                              ["visitDate", "sourceIP", "adRevenue"])

    # --- Bob strolls around ------------------------------------------------
    q1 = q.HailQuery(filter=("visitDate", 10000, 10155),
                     projection=("sourceIP",))
    j1 = mr.run_job(store, q1, splitting="hail")
    show("Q1", "SELECT sourceIP WHERE visitDate BETWEEN '1999..2000'", j1)

    suspicious = int(np.asarray(j1.results["sample"]["sourceIP"])[0])
    q2 = q.HailQuery(filter=("sourceIP", suspicious, suspicious),
                     projection=("searchWord", "duration", "adRevenue"))
    j2 = mr.run_job(store, q2, splitting="hail")
    show("Q2", f"SELECT ... WHERE sourceIP={suspicious}  (strange requests!)", j2)

    q4 = q.HailQuery(filter=("adRevenue", 1, 1700),
                     projection=("searchWord", "duration", "adRevenue"))
    j4 = mr.run_job(store, q4, splitting="hail")
    show("Q4", "SELECT ... WHERE adRevenue BETWEEN 1 AND 17 (dollars)", j4)

    # --- group-by on top (the reduce side) ----------------------------------
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    qp = q.plan(store, q1)
    res = q.read_hail(store, q1, qp)
    rep = store.replicas[int(qp.replica_for_block[0])]
    sums, cnts = mr.spmd_aggregate(mesh, rep.cols["countryCode"],
                                   rep.cols["adRevenue"], res.mask, 256)
    top = int(np.argmax(np.asarray(sums)))
    print(f"GROUP BY countryCode: top country #{top} with "
          f"${float(sums[top]) / 100:.0f} revenue in range")

    # --- a datanode dies mid-session ----------------------------------------
    victim = int(store.replicas[store.replica_by_key("visitDate")].nodes[0])
    store.namenode.kill_node(victim)
    print(f"\n*** datanode {victim} died ***")
    j1b = mr.run_job(store, q1, splitting="hail")
    qp = q.plan(store, q1)
    n_fallback = int((~qp.index_scan).sum())
    show("Q1 again", f"({n_fallback} blocks fell back to full scan)", j1b)
    assert j1b.results["n_rows"] == j1.results["n_rows"], "failover changed results!"
    print("results identical under failure - failover invariant holds")
    store.namenode.revive()


if __name__ == "__main__":
    main()
