"""Quickstart: upload a web log to HAIL, run Bob's first query, compare
against a plain-Hadoop scan.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import mapreduce as mr
from repro.core import query as q
from repro.core import schema as sc
from repro.core import upload as up
from repro.core.parse import format_rows


def main():
    # 1. Bob's web log: 16 blocks x 4096 rows of UserVisits
    cols = sc.gen_uservisits(16 * 4096, seed=0)
    raw = format_rows(sc.USERVISITS, cols, bad_fraction=0.001)
    raw = raw.reshape(16, 4096, -1)
    print(f"log: {raw.size / 1e6:.1f} MB ASCII, {raw.shape[0]} blocks")

    # 2. HAIL upload: parse -> PAX -> 3 replicas, each with its OWN
    #    clustered index (visitDate / sourceIP / adRevenue)
    store, stats = up.hail_upload(
        sc.USERVISITS, raw, ["visitDate", "sourceIP", "adRevenue"])
    print(f"HAIL upload: {stats.wall_s:.2f}s compute, "
          f"{stats.written_bytes / 1e6:.1f} MB written, "
          f"{stats.n_indexes} clustered indexes (zero extra I/O)")

    # 3. Bob's query, annotated exactly like the paper's @HailQuery
    query = q.hail_annotation(
        sc.USERVISITS, filter="@3 between(10000,10155)", projection="{@1}")
    print(f"query: SELECT sourceIP WHERE visitDate BETWEEN 10000 AND 10155")

    job = mr.run_job(store, query, splitting="hail")
    print(f"HAIL:   {job.n_tasks} map tasks, "
          f"{job.results['n_rows']} rows, "
          f"read {job.bytes_read / 1e6:.2f} MB (index scan)")

    # 4. the same query on plain Hadoop (full parse + scan of raw ASCII)
    hstore, _ = up.hdfs_upload(sc.USERVISITS, raw)
    hjob = mr.run_job(hstore, query)
    print(f"Hadoop: {hjob.n_tasks} map tasks, "
          f"{hjob.results['n_rows']} rows, "
          f"read {hjob.bytes_read / 1e6:.2f} MB (full scan)")
    assert job.results["n_rows"] == hjob.results["n_rows"]
    print(f"same answer, {hjob.bytes_read / max(job.bytes_read, 1):.0f}x less I/O, "
          f"{hjob.end_to_end_s / job.end_to_end_s:.1f}x faster end-to-end (simulated cluster)")


if __name__ == "__main__":
    main()
