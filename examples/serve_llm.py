"""Serving driver: batched prefill + decode with KV caches, plus HAIL-backed
request-log analytics (every request is appended to a HAIL store; the ops
dashboard's "which IPs hammered us today?" is an index scan).

  PYTHONPATH=src python examples/serve_llm.py --batch 4 --prompt-len 32 --gen 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.dist.sharding import init_params
from repro.models.model import model_specs
from repro.train.step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="arch id (reduced config is served on CPU)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

    max_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, {"tokens": tok, "pos": pos})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], 1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {t_prefill * 1e3:.0f} ms")
    print(f"decode  {args.gen} steps: {t_decode * 1e3:.0f} ms "
          f"({args.batch * args.gen / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"sample generation[0]: {gen[0].tolist()}")

    # --- request-log analytics lands in HAIL --------------------------------
    from repro.core import mapreduce as mr
    from repro.core import query as q
    from repro.core import schema as sc
    from repro.core import upload as up
    from repro.core.parse import format_rows

    log_schema = sc.Schema("RequestLog", (
        sc.Column("client_ip"), sc.Column("ts"),
        sc.Column("prompt_toks", ascii_width=6),
        sc.Column("gen_toks", ascii_width=6),
        sc.Column("latency_ms", ascii_width=8)))
    n = 8192
    r = np.random.default_rng(0)
    logs = {
        "client_ip": r.integers(0, 1 << 20, n).astype(np.int32),
        "ts": np.arange(n, dtype=np.int32),
        "prompt_toks": np.full(n, args.prompt_len, np.int32),
        "gen_toks": np.full(n, args.gen, np.int32),
        "latency_ms": r.integers(20, 2000, n).astype(np.int32),
    }
    raw = format_rows(log_schema, logs).reshape(8, 1024, -1)
    store, _ = up.hail_upload(log_schema, raw,
                              ["client_ip", "ts", "latency_ms"],
                              partition_size=256)
    slow = q.HailQuery(filter=("latency_ms", 1500, 10**6),
                       projection=("client_ip", "ts"))
    job = mr.run_job(store, slow, splitting="hail")
    print(f"ops query 'requests slower than 1.5s': {job.results['n_rows']} "
          f"rows via index scan, {job.n_tasks} tasks")


if __name__ == "__main__":
    main()
